"""Unit tests for repro.analysis.lint: each rule firing on a minimal
positive and staying quiet on the guarded negative, waiver parsing,
baseline fingerprint gating, the CLI self-test, and a clean run over the
real tree.  Plus regression tests for the fixes the pass flagged
(summarize ratio reporting, FrontendStats rates, sharegpt scale guard).
"""
import json
import textwrap
from types import SimpleNamespace

import numpy as np
import pytest

from repro.analysis.lint import Finding, lint_file, main


def _lint(tmp_path, src, name="mod.py"):
    p = tmp_path / name
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(src))
    return lint_file(str(p))


def _active(findings):
    return [f for f in findings if not f.waived]


def _rules(findings):
    return [f.rule for f in _active(findings)]


# ---------------------------------------------------------------------------
# host-sync-in-hot-path
# ---------------------------------------------------------------------------

def test_host_sync_item_in_hot_path(tmp_path):
    fs = _lint(tmp_path, """
        class Engine:
            def _decode_round(self):
                n = self.lengths.item()
                return n
    """)
    assert _rules(fs) == ["host-sync-in-hot-path"]
    assert ".item()" in fs[0].message


def test_host_sync_reaches_through_helper_calls(tmp_path):
    # step -> self._helper -> module fn -> device_get: still hot
    fs = _lint(tmp_path, """
        import jax

        def _pull(x):
            return jax.device_get(x)

        class Engine:
            def _decode_round(self):
                return self._helper()

            def _helper(self):
                return _pull(self.lengths)
    """)
    assert "host-sync-in-hot-path" in _rules(fs)
    assert "device_get" in _active(fs)[0].message


def test_host_sync_silent_outside_hot_path(tmp_path):
    fs = _lint(tmp_path, """
        class Engine:
            def _decode_round(self):
                return 0

            def debug_dump(self):
                return self.lengths.item()
    """)
    assert _rules(fs) == []


def test_host_sync_flags_float_of_jit_output(tmp_path):
    fs = _lint(tmp_path, """
        class Engine:
            def _decode_round(self):
                out = self._decode_fn(self.cache)
                return float(out)
    """)
    assert "host-sync-in-hot-path" in _rules(fs)


# ---------------------------------------------------------------------------
# use-after-donate
# ---------------------------------------------------------------------------

def test_use_after_donate_flagged(tmp_path):
    fs = _lint(tmp_path, """
        import jax

        class Engine:
            def __init__(self, f):
                self._step_fn = jax.jit(f, donate_argnums=(0,))

            def go(self, tok):
                out = self._step_fn(self.cache, tok)
                return self.cache
    """)
    assert _rules(fs) == ["use-after-donate"]
    assert "self.cache" in fs[0].message and "donated" in fs[0].message


def test_use_after_donate_rebind_is_clean(tmp_path):
    fs = _lint(tmp_path, """
        import jax

        class Engine:
            def __init__(self, f):
                self._step_fn = jax.jit(f, donate_argnums=(0,))

            def go(self, tok):
                self.cache = self._step_fn(self.cache, tok)
                return self.cache
    """)
    assert _rules(fs) == []


# ---------------------------------------------------------------------------
# retrace-hazard
# ---------------------------------------------------------------------------

def test_retrace_container_at_static_position(tmp_path):
    fs = _lint(tmp_path, """
        import jax

        def f(x, shape):
            return x

        _fn = jax.jit(f, static_argnums=(1,))

        def call(x):
            return _fn(x, [1, 2])
    """)
    assert _rules(fs) == ["retrace-hazard"]
    assert "unhashable" in fs[0].message


def test_retrace_jit_inside_loop(tmp_path):
    fs = _lint(tmp_path, """
        import jax

        def rounds(fns):
            out = []
            for f in fns:
                out.append(jax.jit(f))
            return out
    """)
    assert _rules(fs) == ["retrace-hazard"]
    assert "inside a loop" in fs[0].message


def test_retrace_hashable_static_is_clean(tmp_path):
    fs = _lint(tmp_path, """
        import jax

        def f(x, n):
            return x

        _fn = jax.jit(f, static_argnums=(1,))

        def call(x):
            return _fn(x, 8)
    """)
    assert _rules(fs) == []


# ---------------------------------------------------------------------------
# blocking-in-async
# ---------------------------------------------------------------------------

def test_blocking_time_sleep_in_coroutine(tmp_path):
    fs = _lint(tmp_path, """
        import time

        async def poll():
            time.sleep(0.1)
    """)
    assert _rules(fs) == ["blocking-in-async"]
    assert "asyncio.sleep" in fs[0].message


def test_blocking_queue_get_in_coroutine(tmp_path):
    fs = _lint(tmp_path, """
        import queue

        inbox = queue.Queue()

        async def pump():
            return inbox.get()
    """)
    assert _rules(fs) == ["blocking-in-async"]


def test_engine_step_in_coroutine_flagged_unless_offloaded(tmp_path):
    fs = _lint(tmp_path, """
        import asyncio

        async def serve(engine, loop):
            engine.step()
            await loop.run_in_executor(None, lambda: engine.steps(4))
    """)
    assert _rules(fs) == ["blocking-in-async"]
    assert fs[0].line == 5          # the bare step(); the executor one not


def test_sync_code_never_flagged(tmp_path):
    fs = _lint(tmp_path, """
        import time

        def warmup():
            time.sleep(0.1)
    """)
    assert _rules(fs) == []


# ---------------------------------------------------------------------------
# pallas-traced-branch
# ---------------------------------------------------------------------------

def test_pallas_branch_on_traced_value(tmp_path):
    fs = _lint(tmp_path, """
        def decode_kernel(q_ref, acc):
            x = q_ref
            if x > 0:
                return acc
            return acc
    """, name="kernels/attn.py")
    assert _rules(fs) == ["pallas-traced-branch"]
    assert "decode_kernel" in fs[0].message


def test_pallas_shape_branch_is_static(tmp_path):
    fs = _lint(tmp_path, """
        def decode_kernel(q_ref, acc):
            if q_ref.shape[0] > 4:
                return acc
            return acc
    """, name="kernels/attn.py")
    assert _rules(fs) == []


def test_pallas_rule_scoped_to_kernels_dir(tmp_path):
    fs = _lint(tmp_path, """
        def decode_kernel(q_ref, acc):
            if q_ref > 0:
                return acc
            return acc
    """, name="serving/attn.py")
    assert _rules(fs) == []


# ---------------------------------------------------------------------------
# unguarded-div
# ---------------------------------------------------------------------------

def test_unguarded_counter_division(tmp_path):
    fs = _lint(tmp_path, """
        def attainment(self):
            return self.met / self.scored
    """)
    assert _rules(fs) == ["unguarded-div"]
    assert "self.scored" in fs[0].message


def test_div_guarded_by_ternary_is_clean(tmp_path):
    fs = _lint(tmp_path, """
        def attainment(self):
            return self.met / self.scored if self.scored else 1.0
    """)
    assert _rules(fs) == []


def test_div_guarded_by_early_return_is_clean(tmp_path):
    fs = _lint(tmp_path, """
        def attainment(self):
            if not self.scored:
                return 1.0
            return self.met / self.scored
    """)
    assert _rules(fs) == []


def test_div_len_denominator(tmp_path):
    fs = _lint(tmp_path, """
        def mean_ttft(served):
            return sum(served) / len(served)
    """)
    assert _rules(fs) == ["unguarded-div"]


def test_div_max_rebind_is_clean(tmp_path):
    fs = _lint(tmp_path, """
        def rate(done, total):
            total = max(total, 1)
            return done / total
    """)
    assert _rules(fs) == []


# ---------------------------------------------------------------------------
# waivers
# ---------------------------------------------------------------------------

def test_trailing_waiver_with_reason(tmp_path):
    fs = _lint(tmp_path, """
        class Engine:
            def _decode_round(self):
                return self.lengths.item()  # qlint: disable=host-sync-in-hot-path -- single documented sync per round
    """)
    assert _rules(fs) == []
    assert len(fs) == 1 and fs[0].waived
    assert fs[0].waive_reason == "single documented sync per round"


def test_standalone_waiver_covers_next_line(tmp_path):
    fs = _lint(tmp_path, """
        class Engine:
            def _decode_round(self):
                # qlint: disable=host-sync-in-hot-path -- warmup only
                return self.lengths.item()
    """)
    assert _rules(fs) == []
    assert any(f.waived for f in fs)


def test_waiver_missing_reason_is_itself_a_finding(tmp_path):
    fs = _lint(tmp_path, """
        class Engine:
            def _decode_round(self):
                return self.lengths.item()  # qlint: disable=host-sync-in-hot-path
    """)
    assert "waiver-missing-reason" in _rules(fs)


def test_waiver_for_other_rule_does_not_mask(tmp_path):
    fs = _lint(tmp_path, """
        class Engine:
            def _decode_round(self):
                return self.lengths.item()  # qlint: disable=unguarded-div -- wrong rule
    """)
    assert "host-sync-in-hot-path" in _rules(fs)


# ---------------------------------------------------------------------------
# fingerprints + baseline gating via the CLI
# ---------------------------------------------------------------------------

_VIOLATION = """
def attainment(self):
    return self.met / self.scored
"""


def test_fingerprint_is_line_independent():
    a = Finding("unguarded-div", "m.py", 3, 4, "division by `x`")
    b = Finding("unguarded-div", "m.py", 90, 0, "division by `x`")
    c = Finding("unguarded-div", "m.py", 3, 4, "division by `y`")
    assert a.fingerprint == b.fingerprint != c.fingerprint


def test_baseline_gate_is_zero_new_findings(tmp_path, capsys):
    mod = tmp_path / "m.py"
    mod.write_text(_VIOLATION)
    base = tmp_path / "baseline.json"

    assert main([str(mod), "--baseline", str(base)]) == 1
    assert main([str(mod), "--baseline", str(base),
                 "--write-baseline"]) == 0
    assert len(json.loads(base.read_text())["fingerprints"]) == 1
    # baselined finding no longer gates...
    assert main([str(mod), "--baseline", str(base)]) == 0
    # ...but a NEW violation (even shifted lines) does
    mod.write_text("x = 1\n\n" + _VIOLATION +
                   "\ndef r(self):\n    return self.ok / self.count\n")
    capsys.readouterr()                      # drop earlier runs' output
    assert main([str(mod), "--baseline", str(base)]) == 1
    out = capsys.readouterr().out
    assert "self.count" in out and "self.scored" not in out


def test_json_report_includes_waived(tmp_path):
    mod = tmp_path / "m.py"
    mod.write_text("def f(self):\n"
                   "    return self.a / self.scored  "
                   "# qlint: disable=unguarded-div -- test fixture\n")
    report = tmp_path / "report.json"
    assert main([str(mod), "--baseline", str(tmp_path / "b.json"),
                 "--json", str(report)]) == 0
    data = json.loads(report.read_text())
    assert data["summary"] == {"active": 0, "waived": 1, "baselined": 0}
    assert data["findings"][0]["fingerprint"]


# ---------------------------------------------------------------------------
# the real tree + self-test
# ---------------------------------------------------------------------------

def test_repo_tree_is_clean():
    assert main(["src", "--baseline", "qlint_baseline.json"]) == 0


def test_self_test_flags_injected_violation(capsys):
    assert main(["src", "--self-test"]) == 0
    assert "self-test OK" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# regression tests for the fixes this pass flagged
# ---------------------------------------------------------------------------

def test_summarize_zero_request_run():
    from repro.launch.serve import summarize
    ctrl = SimpleNamespace(rejected=[])
    out = summarize([], ctrl, [], t_start=0.0, now=1.0)
    assert out["slo_attainment"] == 1.0      # vacuous, not ZeroDivisionError
    assert out["mean_ttft_s"] is None        # not NaN
    json.dumps(out)                          # stays valid JSON


def test_frontend_stats_rates_guard_zero_denominators():
    from repro.serving.frontend import FrontendStats
    s = FrontendStats()
    assert s.acceptance_rate == 1.0
    assert s.rejection_rate == 0.0
    assert s.expiry_rate == 0.0
    assert s.mean_tokens_per_accepted == 0.0
    s.submitted, s.accepted, s.rejected_full = 4, 3, 1
    s.expired, s.tokens_streamed = 1, 30
    assert s.acceptance_rate == pytest.approx(0.75)
    assert s.rejection_rate == pytest.approx(0.25)
    assert s.expiry_rate == pytest.approx(1 / 3)
    assert s.mean_tokens_per_accepted == pytest.approx(10.0)


def test_sharegpt_mega_scale_survives_zero_total(monkeypatch):
    from repro.data import sharegpt_synth as sg
    monkeypatch.setattr(                     # dataclass is frozen: patch class
        sg.TokenDistribution, "sample",
        lambda self, rng, n: (np.zeros(n), np.zeros(n)))
    ins, outs = sg.sample_lengths(np.random.default_rng(0), 32,
                                  mega_fraction=1.0)
    assert np.isfinite(ins).all() and np.isfinite(outs).all()
