"""Regression tests for the queue-layer bug batch:

  * per-model hardware calibration (launch/serve.calibrate_registry) —
    each arch gets a profile from ITS OWN engine, not a copy of arch-1's
  * SLO attainment accounting — rejected / expired / stranded requests
    count as misses instead of silently inflating attainment
  * submit liveness — a request classified into a group absent from every
    virtual queue re-places the group instead of stranding
  * predict_violation — a queued group whose model an instance cannot
    serve is skipped (no solver thrash); an entirely unservable model
    raises once, at submit time
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.core.global_scheduler import GlobalScheduler, InstanceInfo
from repro.core.qlm import QLMConfig, QLMController
from repro.core.request import Request, make_request
from repro.core.request_group import RequestGroup
from repro.core.rwt_estimator import HardwareProfile
from repro.core.virtual_queue import VirtualQueue
from repro.launch.serve import calibrate_registry, summarize
from repro.models import build_model
from repro.serving import EngineConfig


def _hw(**kw):
    base = dict(prefill_time=0.05, decode_per_token=0.02, inefficiency=1.2,
                token_capacity=512, swap_time=0.2, model_max_tokens=32)
    base.update(kw)
    return HardwareProfile(**base)


def _instance(iid, models, current=None):
    return InstanceInfo(iid, {m: _hw() for m in models}, current,
                        VirtualQueue(iid))


def _controller(instances, **cfg):
    cfg.setdefault("avg_batch_size", 4)
    cfg.setdefault("reschedule_on_arrival", False)
    return QLMController(instances, QLMConfig(**cfg))


# ---------------------------------------------------------------------------
# satellite 1: per-model calibration
# ---------------------------------------------------------------------------

def test_calibrate_registry_per_model_profiles():
    """Each model is calibrated on its own engine: profiles for models of
    different sizes must differ (the old code copied arch-1's profile to
    every model)."""
    key = jax.random.key(0)
    registry = {}
    for name, (layers, d) in (("granite-3-2b", (1, 64)),
                              ("h2o-danube-1.8b", (4, 256))):
        cfg = ARCHITECTURES[name].reduced(num_layers=layers, d_model=d)
        model = build_model(cfg)
        registry[name] = (model, model.init(key))
    ecfg = EngineConfig(max_slots=2, max_seq_len=64)
    hw = calibrate_registry(registry, ecfg)
    assert set(hw) == set(registry)
    for p in hw.values():
        assert isinstance(p, HardwareProfile)
        assert p.decode_per_token > 0 and p.token_capacity > 0
    a, b = hw["granite-3-2b"], hw["h2o-danube-1.8b"]
    # a 4-layer/256-d model cannot time identically to a 1-layer/64-d one
    assert (a.prefill_time, a.decode_per_token) \
        != (b.prefill_time, b.decode_per_token)


# ---------------------------------------------------------------------------
# satellite 2: attainment accounting
# ---------------------------------------------------------------------------

def test_attainment_counts_unserved_deadline_misses():
    inst = _instance(0, ["m"])
    c = _controller([inst])
    t0 = 100.0

    served = make_request([1, 2, 3], "m", "interactive", arrival_time=t0)
    c.submit(served, t0)
    served.first_token_time = t0 + 1.0       # TTFT 1 s: met

    stranded = make_request([1, 2, 3], "m", "interactive", arrival_time=t0)
    c.submit(stranded, t0)                   # never served

    fresh = make_request([1, 2, 3], "m", "interactive", arrival_time=t0)
    c.submit(fresh, t0)                      # queued, deadline not yet due

    rejected = make_request([4, 5], "m", "interactive", arrival_time=t0)
    c.record_rejection(rejected, t0)
    assert rejected.rejected and rejected.finished()

    # past the interactive deadline: served=hit, stranded=miss,
    # rejected=miss, fresh... also past deadline at t0+30 -> miss
    now = t0 + 30.0
    assert c.slo_attainment(now) == pytest.approx(1 / 4)
    # before any deadline passes only the rejection is a definite miss
    assert c.slo_attainment(t0 + 1.0) == pytest.approx(1 / 2)
    # legacy call (no now): unstarted queued requests are unscored
    assert c.slo_attainment() == pytest.approx(1 / 2)


def test_summarize_mirrors_attainment_accounting():
    inst = _instance(0, ["m"])
    c = _controller([inst])
    t0 = 50.0
    reqs = []
    for _ in range(3):
        r = make_request([1, 2], "m", "interactive", arrival_time=t0)
        c.submit(r, t0)
        reqs.append(r)
    reqs[0].first_token_time = t0 + 0.5      # served, met
    reqs[0].completion_time = t0 + 1.0
    reqs[1].expired = True                   # swept by the front end
    reqs[1].completion_time = t0 + 21.0
    # reqs[2]: stranded unstarted, past deadline at `now`
    rej = make_request([9], "m", "interactive", arrival_time=t0)
    c.record_rejection(rej, t0)
    reqs.append(rej)

    class _Stats:
        evictions = model_swaps = tokens_generated = 0
        prefix_hits = prefix_shared_tokens = 0

    class _Eng:
        stats = _Stats()

    stats = summarize(reqs, c, [_Eng()], t0, t0 + 30.0)
    assert stats["served"] == 1
    assert stats["rejected"] == 1
    assert stats["dropped_unserved"] == 3    # expired + stranded + rejected
    assert stats["slo_attainment"] == pytest.approx(1 / 4)


# ---------------------------------------------------------------------------
# satellite 3: stranded-group liveness
# ---------------------------------------------------------------------------

def test_submit_replaces_group_absent_from_all_vqs():
    inst = _instance(0, ["m"])
    c = _controller([inst])
    t0 = 10.0
    r1 = make_request([1, 2, 3], "m", "batch1", arrival_time=t0)
    c.submit(r1, t0)
    g = c.groups[0]
    assert g in inst.virtual_queue.groups

    # an infeasible solve / EDF fallback can rewrite the VQ without this
    # group; a later same-group arrival must re-place it
    inst.virtual_queue.set_order([])
    assert not inst.virtual_queue.groups

    r2 = make_request([1, 2, 3], "m", "batch1", arrival_time=t0 + 0.1)
    c.submit(r2, t0 + 0.1)
    assert r2.group_id == g.group_id         # classified into the old group
    assert any(q is g for q in inst.virtual_queue.groups), \
        "group gained a request while absent from every VQ and was not re-placed"
    # the request is reachable: the VQ can actually hand it out
    assert inst.virtual_queue.next_request() is r1


# ---------------------------------------------------------------------------
# satellite 4: unservable models
# ---------------------------------------------------------------------------

def test_submit_rejects_when_no_instance_serves_model():
    """An unservable model is a recorded 400-style rejection (an
    attainment miss), not an exception out of the serve path."""
    c = _controller([_instance(0, ["m1"])])
    r = make_request([1, 2], "m2", "batch1", arrival_time=0.0)
    assert c.submit(r, 0.0) is False
    assert r.rejected and r.finished()
    assert r in c.rejected
    assert not c.global_queue and not c.groups   # never admitted
    assert c.slo_attainment() < 1.0


def test_predict_violation_skips_unservable_group():
    """A group queued on an instance that lacks its model's profile must
    not read as a violation forever (the old code returned True on every
    tick, re-solving with no possible improvement)."""
    a = _instance(0, ["m1"], current="m1")
    b = _instance(1, ["m1", "m2"], current="m2")
    sched = GlobalScheduler()
    now = 0.0
    g = RequestGroup(model="m2", slo=3600.0)
    g.add(Request(prompt_tokens=[1, 2, 3], model="m2", slo=3600.0,
                  arrival_time=now, max_new_tokens=4, slo_class="batch2"))
    # force the mismatch: an m2 group parked on the m1-only instance
    a.virtual_queue.groups.append(g)
    assert sched.violations([a, b], now) == []
    assert sched.predict_violation([a, b], now) is False

    # sanity: the same group with an impossible deadline on the SERVABLE
    # instance still registers
    g2 = RequestGroup(model="m1", slo=0.0)
    g2.add(Request(prompt_tokens=[1] * 8, model="m1", slo=0.0,
                   arrival_time=now - 10.0, max_new_tokens=32,
                   slo_class="interactive"))
    a.virtual_queue.groups.append(g2)
    assert a in sched.violations([a, b], now)


def test_violations_slo_ceiling_filters_trigger_not_drain():
    """With a ceiling, only interactive-class groups TRIGGER, but batch
    work queued ahead still contributes drain to the walk."""
    inst = _instance(0, ["m"], current="m")
    sched = GlobalScheduler()
    now = 0.0
    slow = _hw(decode_per_token=5.0, prefill_time=5.0)
    inst.hw_by_model["m"] = slow
    batch = RequestGroup(model="m", slo=3600.0)
    for _ in range(4):
        batch.add(Request(prompt_tokens=[1] * 16, model="m", slo=3600.0,
                          arrival_time=now, max_new_tokens=32,
                          slo_class="batch2"))
    inter = RequestGroup(model="m", slo=20.0)
    inter.add(Request(prompt_tokens=[1] * 8, model="m", slo=20.0,
                      arrival_time=now, max_new_tokens=8,
                      slo_class="interactive"))
    inst.virtual_queue.set_order([batch, inter])
    # batch group alone never violates under the interactive ceiling...
    inst2 = _instance(1, ["m"], current="m")
    inst2.hw_by_model["m"] = slow
    inst2.virtual_queue.set_order([batch])
    assert sched.violations([inst2], now, slo_ceiling=20.0) == []
    # ...but its drain ahead of the interactive group IS what blows the
    # interactive deadline
    assert inst in sched.violations([inst], now, slo_ceiling=20.0)
