"""Beyond-paper perf levers (EXPERIMENTS §Perf): numerics must be exact or
tightly bounded vs the paper-faithful baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.models import build_model, materialize_batch


def test_grouped_moe_dispatch_matches_ungrouped():
    cfg = ARCHITECTURES["qwen3-moe-30b-a3b"].reduced()
    big_cap = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    cfg0 = dataclasses.replace(cfg, moe=big_cap)
    cfg4 = dataclasses.replace(cfg, moe=dataclasses.replace(big_cap, dispatch_groups=4))
    m0, m4 = build_model(cfg0), build_model(cfg4)
    params = m0.init(jax.random.key(0))
    batch = materialize_batch(cfg0, 2, 16, "train", jax.random.key(1))
    l0, _ = m0.loss(params, batch)
    l4, _ = m4.loss(params, batch)
    # ample capacity => identical token->expert assignment per group
    np.testing.assert_allclose(float(l0), float(l4), rtol=1e-6)


def test_chunked_train_attention_exact():
    cfg = ARCHITECTURES["granite-3-2b"].reduced()
    cfg_c = dataclasses.replace(cfg, train_attn_chunk=8)
    m, mc = build_model(cfg), build_model(cfg_c)
    params = m.init(jax.random.key(0))
    batch = materialize_batch(cfg, 2, 32, "train", jax.random.key(1))
    l, _ = m.loss(params, batch)
    lc, _ = mc.loss(params, batch)
    np.testing.assert_allclose(float(l), float(lc), rtol=1e-5)


def test_chunked_attention_with_sliding_window():
    cfg = ARCHITECTURES["h2o-danube-1.8b"].reduced()  # window=64 reduced
    cfg_c = dataclasses.replace(cfg, train_attn_chunk=8)
    m, mc = build_model(cfg), build_model(cfg_c)
    params = m.init(jax.random.key(0))
    batch = materialize_batch(cfg, 2, 32, "train", jax.random.key(1))
    l, _ = m.loss(params, batch)
    lc, _ = mc.loss(params, batch)
    np.testing.assert_allclose(float(l), float(lc), rtol=1e-5)


def test_kv_quant_cache_close_and_greedy_stable():
    cfg = ARCHITECTURES["granite-3-2b"].reduced()
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    m, mq = build_model(cfg), build_model(cfgq)
    params = m.init(jax.random.key(0))
    B, L, MAX = 2, 12, 32
    batch = materialize_batch(cfg, B, L, "prefill", jax.random.key(1))
    c1 = m.init_cache(B, MAX)
    c2 = mq.init_cache(B, MAX)
    assert c2["k"].dtype == jnp.int8
    l1, c1 = m.prefill(params, batch, c1)
    l2, c2 = mq.prefill(params, batch, c2)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)
    lengths = jnp.full((B,), L, jnp.int32)
    t1 = jnp.argmax(l1, -1).astype(jnp.int32)
    t2 = jnp.argmax(l2, -1).astype(jnp.int32)
    for _ in range(4):
        l1, c1 = m.decode_step(params, c1, t1, lengths)
        l2, c2 = mq.decode_step(params, c2, t2, lengths)
        a1 = jnp.argmax(l1, -1)
        a2 = jnp.argmax(l2, -1)
        # int8 quantization noise may only flip the argmax on a near-tie:
        # where they disagree, the fp margin between the two candidates must
        # be tiny (exact equality is flaky under load-order-dependent XLA
        # fusion differences).
        top = jnp.take_along_axis(l1, a1[:, None], -1)[:, 0]
        alt = jnp.take_along_axis(l1, a2[:, None], -1)[:, 0]
        assert bool(jnp.all(jnp.where(a1 == a2, True, top - alt < 5e-2)))
        # keep both paths on the same (fp-greedy) token stream so the caches
        # stay comparable even after a tolerated near-tie flip
        t1 = t2 = a1.astype(jnp.int32)
        lengths = lengths + 1
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=5e-2)


def test_kv_quant_swa_rolling_cache():
    cfg = dataclasses.replace(ARCHITECTURES["h2o-danube-1.8b"].reduced(),
                              kv_quant=True)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    B, L, MAX = 1, 16, 128  # window (64) > L: plain path; then long prompt
    batch = materialize_batch(cfg, B, L, "prefill", jax.random.key(1))
    cache = m.init_cache(B, MAX)
    logits, cache = m.prefill(params, batch, cache)
    assert bool(jnp.all(jnp.isfinite(logits)))
    lengths = jnp.full((B,), L, jnp.int32)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = m.decode_step(params, cache, tok, lengths)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        lengths = lengths + 1
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_decode_attention_quant_kernel_matches_ref():
    from repro.kernels.decode_attention import decode_attention_quant
    from repro.kernels import ref
    from repro.models.attention import _dequantize_kv, _quantize_kv
    ks = jax.random.split(jax.random.key(5), 4)
    B, H, KVH, S, D = 2, 8, 2, 128, 32
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, KVH, S, D))
    v = jax.random.normal(ks[2], (B, KVH, S, D))
    lengths = jax.random.randint(ks[3], (B,), 1, S + 1, jnp.int32)
    kq, kscale = _quantize_kv(k)
    vq, vscale = _quantize_kv(v)
    out = decode_attention_quant(q, kq, vq, kscale, vscale, lengths,
                                 block_k=32, interpret=True)
    want = ref.decode_attention_ref(q, _dequantize_kv(kq, kscale, jnp.float32),
                                    _dequantize_kv(vq, vscale, jnp.float32),
                                    lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_count_objective_solver():
    import random
    from repro.core.solver import GroupSpec, InstanceSpec, evaluate, solve
    rng = random.Random(3)
    instances = [InstanceSpec(0, "A", {"A": 1.0})]
    # one huge group vs three small ones; penalty objective may sacrifice
    # the three to help the one — count objective must not
    groups = [
        GroupSpec(0, "A", slo=5.0, drain_time={0: 10.0}, size=1.0),
        GroupSpec(1, "A", slo=12.0, drain_time={0: 2.0}, size=50.0),
        GroupSpec(2, "A", slo=14.0, drain_time={0: 2.0}, size=50.0),
    ]
    sol = solve(groups, instances, objective="count")
    count, _ = evaluate(sol.assignment, groups, instances, "count")
    # serving the two big groups first violates only the small one (count 1)
    assert count <= 1.0


def test_seq_sharded_activations_flag_noop_without_mesh():
    """shard_activations_seq must not break CPU execution (no mesh)."""
    cfg = dataclasses.replace(ARCHITECTURES["granite-3-2b"].reduced(),
                              shard_activations_seq=False)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch = materialize_batch(cfg, 2, 16, "train", jax.random.key(1))
    loss, _ = m.loss(params, batch)
    assert np.isfinite(float(loss))


def test_pallas_attention_backend_matches_jnp():
    """use_pallas_attention routes train+decode through the Pallas kernels
    (interpret mode on CPU) — must match the jnp path."""
    cfg = ARCHITECTURES["granite-3-2b"].reduced(num_layers=2, d_model=128)
    cfgp = dataclasses.replace(cfg, use_pallas_attention=True)
    m, mp = build_model(cfg), build_model(cfgp)
    params = m.init(jax.random.key(0))
    batch = materialize_batch(cfg, 1, 16, "train", jax.random.key(1))
    l1, _ = m.loss(params, batch)
    l2, _ = mp.loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)

    B, L, MAX = 1, 8, 32
    pb = materialize_batch(cfg, B, L, "prefill", jax.random.key(2))
    c1, c2 = m.init_cache(B, MAX), mp.init_cache(B, MAX)
    g1, c1 = m.prefill(params, pb, c1)
    g2, c2 = mp.prefill(params, pb, c2)
    lengths = jnp.full((B,), L, jnp.int32)
    t = jnp.argmax(g1, -1).astype(jnp.int32)
    for _ in range(2):
        g1, c1 = m.decode_step(params, c1, t, lengths)
        g2, c2 = mp.decode_step(params, c2, t, lengths)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-4)
        t = jnp.argmax(g1, -1).astype(jnp.int32)
        lengths = lengths + 1
