"""Mutation tests for repro.analysis.invariants: seed each corruption the
checker exists to catch (refcount skew, leaked block, double-free, slot
table desync) and assert it is caught with an actionable message naming
the block/seq involved.  Plus queue-layer checks and the engine
round-boundary hook (EngineConfig.debug_invariants)."""
import os

import jax
import numpy as np
import pytest

from repro.analysis.invariants import (InvariantSampler, InvariantViolation,
                                       check_block_manager, check_engine,
                                       check_queue_layer, invariants_enabled)
from repro.core.global_scheduler import InstanceInfo
from repro.core.qlm import QLMConfig, QLMController
from repro.core.request import make_request
from repro.core.request_group import RequestGroup
from repro.core.virtual_queue import VirtualQueue
from repro.serving.kv_cache import BlockManager


def _bm(blocks=16, block_size=4, slot_rows=4):
    bm = BlockManager(blocks, block_size, cache_freed=True)
    bm.attach_slot_table(slot_rows, blocks)
    return bm


# ---------------------------------------------------------------------------
# clean states pass
# ---------------------------------------------------------------------------

def test_clean_lifecycle_passes():
    bm = _bm()
    bm.allocate(1, 7)
    bm.bind_slot(1, 0)
    check_block_manager(bm)
    bm.extend(1, 9)
    bm.append_token(1)
    check_block_manager(bm)
    bm.register_prefix(1, list(range(8)), 8)
    bm.fork(1, 2)
    bm.bind_slot(2, 1)
    check_block_manager(bm)
    bm.free(2)
    check_block_manager(bm)
    kept, dropped = bm.evict_split(1)
    check_block_manager(bm)
    bm.free(1)
    check_block_manager(bm)
    bm.reset()
    check_block_manager(bm)


# ---------------------------------------------------------------------------
# the four seeded corruptions
# ---------------------------------------------------------------------------

def test_corrupted_refcount_is_caught():
    bm = _bm()
    bm.allocate(1, 7)
    b = bm.block_table(1)[0]
    bm._ref[b] += 1                      # refcount skew, no real owner
    with pytest.raises(InvariantViolation) as e:
        check_block_manager(bm)
    msg = str(e.value)
    assert f"block {b}" in msg and "refcount" in msg


def test_leaked_block_is_caught():
    bm = _bm()
    bm.allocate(1, 7)
    leaked = bm._free.pop()              # vanishes from every partition
    with pytest.raises(InvariantViolation) as e:
        check_block_manager(bm)
    msg = str(e.value)
    assert "conservation" in msg and str(leaked) in msg


def test_double_free_is_caught():
    bm = _bm()
    bm.allocate(1, 7)
    b = bm.block_table(1)[0]
    bm._free.append(b)                   # freed while seq 1 still holds it
    with pytest.raises(InvariantViolation) as e:
        check_block_manager(bm)
    msg = str(e.value)
    assert f"block {b}" in msg
    assert "free" in msg and "seq" in msg  # names both sides of the bug


def test_slot_table_desync_is_caught():
    bm = _bm()
    bm.allocate(1, 7)
    bm.bind_slot(1, 2)
    real = bm.block_table(1)[0]
    bm._table[2, 0] = (real + 1) % bm.num_blocks   # stale incremental row
    with pytest.raises(InvariantViolation) as e:
        check_block_manager(bm)
    msg = str(e.value)
    assert "row 2" in msg and "seq 1" in msg and "desync" in msg


def test_freed_seq_scrubs_pending_cow_ops():
    # fork() queues a deferred COW op for the forked seq's partial tail
    # block; freeing that seq before the engine drains take_cow_ops()
    # must drop the op, or the released dst block can be reallocated and
    # then clobbered by the late copy.
    bm = _bm()
    bm.allocate(1, 7)
    bm.register_prefix(1, list(range(8)), 8)
    bm.fork(1, 2)
    assert any(True for _ in bm._cow_ops), "fork should queue a COW op"
    bm.free(2)
    free = set(bm._free)
    assert all(d not in free for _, d in bm._cow_ops)
    check_block_manager(bm)
    # the block is reallocatable and the drained ops never touch it
    bm.allocate(3, 7)
    owned = set(bm.block_table(3))
    assert all(d not in owned for _, d in bm.take_cow_ops())


def test_pin_exceeding_refcount_is_caught():
    bm = _bm()
    bm.allocate(1, 7)
    b = bm.block_table(1)[0]
    bm._pins[b] = bm.ref_count(b) + 1
    with pytest.raises(InvariantViolation) as e:
        check_block_manager(bm)
    assert f"block {b}" in str(e.value) and "pin" in str(e.value)


# ---------------------------------------------------------------------------
# queue layer
# ---------------------------------------------------------------------------

def _controller():
    inst = InstanceInfo(0, {}, "m", VirtualQueue(0))
    return QLMController([inst], QLMConfig(reschedule_on_arrival=False)), inst


def _grouped_request(ctrl, inst, *, place=True):
    r = make_request([1, 2, 3], "m", "interactive", arrival_time=0.0)
    g = RequestGroup(model="m", slo=r.slo)
    g.add(r)
    ctrl.groups.append(g)
    ctrl.global_queue.append(r)
    if place:
        inst.virtual_queue.groups.append(g)
    return r, g


def test_queue_layer_clean_passes():
    ctrl, inst = _controller()
    _grouped_request(ctrl, inst)
    check_queue_layer(ctrl)


def test_stranded_group_is_caught():
    ctrl, inst = _controller()
    r, g = _grouped_request(ctrl, inst, place=False)
    with pytest.raises(InvariantViolation) as e:
        check_queue_layer(ctrl)
    assert f"group {g.group_id}" in str(e.value)
    assert "stranded" in str(e.value)


def test_double_placed_group_is_caught():
    ctrl, inst = _controller()
    r, g = _grouped_request(ctrl, inst)
    inst2 = InstanceInfo(1, {}, "m", VirtualQueue(1))
    inst2.virtual_queue.groups.append(g)
    ctrl.instances.append(inst2)
    with pytest.raises(InvariantViolation) as e:
        check_queue_layer(ctrl)
    assert f"group {g.group_id}" in str(e.value)
    assert "2 virtual queues" in str(e.value)


def test_unowned_request_is_caught():
    ctrl, inst = _controller()
    r = make_request([1, 2], "m", "interactive", arrival_time=0.0)
    ctrl.global_queue.append(r)          # queued but in no group
    with pytest.raises(InvariantViolation) as e:
        check_queue_layer(ctrl)
    assert f"request {r.req_id}" in str(e.value)
    assert "0 group" in str(e.value)


def test_group_slo_not_member_min_is_caught():
    ctrl, inst = _controller()
    r, g = _grouped_request(ctrl, inst)
    g.slo = r.slo * 4                    # stale / corrupted group deadline
    with pytest.raises(InvariantViolation) as e:
        check_queue_layer(ctrl)
    assert f"group {g.group_id}" in str(e.value)
    assert "member minimum" in str(e.value)


# ---------------------------------------------------------------------------
# engine round-boundary hook (EngineConfig.debug_invariants)
# ---------------------------------------------------------------------------

def test_engine_debug_invariants_hook(tiny_engine):
    eng = tiny_engine
    req = make_request(list(range(12)), eng.model_name, "batch1",
                       arrival_time=0.0, max_new_tokens=4)
    eng.admit(req)
    for _ in range(8):
        eng.step()                       # checks run at every boundary
        if req.finished():
            break
    assert req.finished()
    # now corrupt the pool and run another round: the hook must trip
    r2 = make_request(list(range(12)), eng.model_name, "batch1",
                      arrival_time=0.0, max_new_tokens=4)
    eng.admit(r2)
    b = eng.block_mgr.block_table(r2.req_id)[0]
    eng.block_mgr._ref[b] += 1
    with pytest.raises(InvariantViolation):
        eng.step()


@pytest.fixture(scope="module")
def tiny_engine():
    from repro.configs import ARCHITECTURES
    from repro.models import build_model
    from repro.serving import ContinuousBatchingEngine, EngineConfig
    cfg = ARCHITECTURES["granite-3-2b"].reduced(num_layers=1, d_model=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    ecfg = EngineConfig(max_slots=2, max_seq_len=64, block_size=8,
                        attention_backend="paged-xla",
                        debug_invariants=True)
    return ContinuousBatchingEngine(model, params, ecfg,
                                    model_name="granite-3-2b")


# ---------------------------------------------------------------------------
# enablement plumbing
# ---------------------------------------------------------------------------

def test_env_enablement(monkeypatch):
    monkeypatch.delenv("QLINT_INVARIANTS", raising=False)
    assert not invariants_enabled()
    monkeypatch.setenv("QLINT_INVARIANTS", "0")
    assert not invariants_enabled()
    monkeypatch.setenv("QLINT_INVARIANTS", "1")
    assert invariants_enabled()


def test_sampler(monkeypatch):
    monkeypatch.setenv("QLINT_INVARIANTS_SAMPLE", "3")
    s = InvariantSampler()
    assert [s.due() for _ in range(6)] == [False, False, True,
                                           False, False, True]
    monkeypatch.setenv("QLINT_INVARIANTS_SAMPLE", "not-a-number")
    assert InvariantSampler().every == 1
