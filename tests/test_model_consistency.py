"""Numerical consistency: serving paths must agree with the train-path
forward, and the chunked SSD scan with the naive recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests only
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHITECTURES
from repro.models import build_model
from repro.models.ssm import ssd_chunked, ssd_recurrent_reference

CONSISTENCY_ARCHS = ["granite-3-2b", "h2o-danube-1.8b", "qwen1.5-32b",
                     "mamba2-130m", "zamba2-1.2b", "whisper-medium"]


def _prefill_batch(cfg, tokens, key):
    batch = {"tokens": tokens}
    if cfg.vision is not None:
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            key, (tokens.shape[0], cfg.vision.num_patch_tokens, cfg.d_model))
    if cfg.encoder is not None:
        batch["frame_embeds"] = 0.02 * jax.random.normal(
            key, (tokens.shape[0], cfg.encoder.num_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", CONSISTENCY_ARCHS)
def test_decode_matches_prefill(name):
    """Greedy decode continuing a prefix must equal prefilling the longer
    prefix (teacher-forced): logits at the same position agree."""
    cfg = ARCHITECTURES[name].reduced()
    model = build_model(cfg)
    key = jax.random.key(3)
    params = model.init(key)
    B, L, MAX = 2, 10, 32
    tokens = jax.random.randint(key, (B, L + 3), 0, cfg.vocab_size, jnp.int32)

    # path A: prefill the full L+3 prompt
    cacheA = model.init_cache(B, MAX)
    logitsA, _ = model.prefill(params, _prefill_batch(cfg, tokens, key), cacheA)

    # path B: prefill L, then decode the remaining 3 teacher-forced tokens
    cacheB = model.init_cache(B, MAX)
    logitsB, cacheB = model.prefill(
        params, _prefill_batch(cfg, tokens[:, :L], key), cacheB)
    plen = L + (cfg.vision.num_patch_tokens if cfg.vision is not None else 0)
    lengths = jnp.full((B,), plen, jnp.int32)
    for t in range(3):
        logitsB, cacheB = model.decode_step(params, cacheB, tokens[:, L + t], lengths)
        lengths = lengths + 1

    np.testing.assert_allclose(np.asarray(logitsA), np.asarray(logitsB),
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunked_matches_recurrence():
    key = jax.random.key(0)
    ks = jax.random.split(key, 5)
    B, L, H, P, G, N = 2, 96, 4, 16, 2, 8
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, L, G, N))
    Cm = jax.random.normal(ks[4], (B, L, G, N))
    for chunk in (8, 16, 32, 96):
        y1, h1 = ssd_chunked(x, dt, A, Bm, Cm, chunk)
        y2, h2 = ssd_recurrent_reference(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                                   rtol=1e-4, atol=1e-4)


@settings(max_examples=12, deadline=None)
@given(
    L=st.sampled_from([16, 32, 64]),
    chunk=st.sampled_from([8, 16]),
    H=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**16),
)
def test_ssd_chunked_property(L, chunk, H, seed):
    """Property: chunked == recurrent for random shapes/params, with and
    without an initial state."""
    key = jax.random.key(seed)
    ks = jax.random.split(key, 6)
    B, P, G, N = 1, 8, 1, 4
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, L, G, N))
    Cm = jax.random.normal(ks[4], (B, L, G, N))
    h0 = jax.random.normal(ks[5], (B, H, N, P))
    y1, h1 = ssd_chunked(x, dt, A, Bm, Cm, chunk, h0)
    y2, h2 = ssd_recurrent_reference(x, dt, A, Bm, Cm, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-3, atol=1e-3)


def test_sliding_window_matches_full_for_short_seq():
    """SWA with window >= seq equals full attention (danube family)."""
    import dataclasses
    cfg = ARCHITECTURES["h2o-danube-1.8b"].reduced()
    cfg_full = dataclasses.replace(cfg, sliding_window=None)
    m1, m2 = build_model(cfg), build_model(cfg_full)
    params = m1.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 20), 0, cfg.vocab_size, jnp.int32)
    l1, _ = m1.loss(params, {"tokens": tokens})
    l2, _ = m2.loss(params, {"tokens": tokens})
    # window (64 reduced) > seq 19 => identical
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
