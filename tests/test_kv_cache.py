"""BlockManager unit + property tests (paged-KV accounting invariants)."""
import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests only
from hypothesis import given, settings, strategies as st

from repro.serving.kv_cache import BlockManager, OutOfBlocksError


def test_basic_alloc_free():
    bm = BlockManager(num_blocks=10, block_size=4)
    assert bm.token_capacity == 40
    blocks = bm.allocate(1, 9)  # 3 blocks
    assert len(blocks) == 3 and bm.free_blocks == 7
    bm.free(1)
    assert bm.free_blocks == 10


def test_append_token_grows_blocks():
    bm = BlockManager(num_blocks=3, block_size=2)
    bm.allocate(1, 2)  # exactly 1 block
    assert bm.append_token(1)      # needs a 2nd block
    assert bm.free_blocks == 1
    assert bm.append_token(1)      # fits in block 2
    assert bm.append_token(1)      # needs 3rd block
    assert bm.free_blocks == 0
    assert bm.append_token(1)      # fits
    assert not bm.append_token(1)  # OOM -> caller preempts


def test_out_of_blocks_raises():
    bm = BlockManager(num_blocks=2, block_size=4)
    with pytest.raises(OutOfBlocksError):
        bm.allocate(1, 100)


def test_watermark_respected():
    bm = BlockManager(num_blocks=100, block_size=1, watermark=0.1)
    assert bm.can_allocate(90)
    assert not bm.can_allocate(91)
    assert bm.can_allocate(100, respect_watermark=False)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "append", "free"]),
                          st.integers(0, 7), st.integers(1, 30)),
                max_size=60))
def test_accounting_invariants(ops):
    """free + used == total; token accounting matches block tables."""
    bm = BlockManager(num_blocks=16, block_size=4)
    for op, sid, ntok in ops:
        if op == "alloc" and not bm.has(sid):
            if bm.blocks_needed(ntok) <= bm.free_blocks:
                bm.allocate(sid, ntok)
        elif op == "append" and bm.has(sid):
            bm.append_token(sid)
        elif op == "free":
            bm.free(sid)
        assert bm.free_blocks + bm.used_blocks == bm.num_blocks
        for s in list(bm._seqs):
            alloc = bm._seqs[s]
            assert len(alloc.block_table) == bm.blocks_needed(alloc.num_tokens) \
                or alloc.num_tokens % bm.block_size == 0
            assert alloc.num_tokens <= len(alloc.block_table) * bm.block_size
        # no block is double-owned
        owned = [b for s in bm._seqs.values() for b in s.block_table]
        assert len(owned) == len(set(owned))
        assert not (set(owned) & set(bm._free))
