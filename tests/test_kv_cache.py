"""BlockManager unit tests (paged-KV accounting).

The random-interleaving property tests live in
``test_kv_cache_properties.py`` (hypothesis, auto-skipped when absent) so
these unit tests run even without the optional dep.
"""
import pytest

from repro.serving.kv_cache import BlockManager, OutOfBlocksError


def test_basic_alloc_free():
    bm = BlockManager(num_blocks=10, block_size=4)
    assert bm.token_capacity == 40
    blocks = bm.allocate(1, 9)  # 3 blocks
    assert len(blocks) == 3 and bm.free_blocks == 7
    bm.free(1)
    assert bm.free_blocks == 10


def test_append_token_grows_blocks():
    bm = BlockManager(num_blocks=3, block_size=2)
    bm.allocate(1, 2)  # exactly 1 block
    assert bm.append_token(1)      # needs a 2nd block
    assert bm.free_blocks == 1
    assert bm.append_token(1)      # fits in block 2
    assert bm.append_token(1)      # needs 3rd block
    assert bm.free_blocks == 0
    assert bm.append_token(1)      # fits
    assert not bm.append_token(1)  # OOM -> caller preempts


def test_out_of_blocks_raises():
    bm = BlockManager(num_blocks=2, block_size=4)
    with pytest.raises(OutOfBlocksError):
        bm.allocate(1, 100)


def test_watermark_respected():
    bm = BlockManager(num_blocks=100, block_size=1, watermark=0.1)
    assert bm.can_allocate(90)
    assert not bm.can_allocate(91)
    assert bm.can_allocate(100, respect_watermark=False)


def test_allocate_agrees_with_can_allocate():
    """The admission check and the allocation it green-lights must enforce
    the SAME watermark bound (allocate used to ignore it and could eat the
    reserve can_allocate had just refused)."""
    bm = BlockManager(num_blocks=10, block_size=4, watermark=0.2)  # 2 reserved
    # boundary: exactly at the watermark edge
    assert bm.can_allocate(32)            # 8 blocks == 10 - 2
    assert not bm.can_allocate(33)        # 9 blocks > 10 - 2
    with pytest.raises(OutOfBlocksError):
        bm.allocate(1, 33)                # allocate now refuses it too
    assert bm.free_blocks == 10           # failed allocate left no residue
    bm.allocate(1, 32)                    # the green-lit amount succeeds
    assert bm.free_blocks == 2
    # the explicit escape hatch may dip into the reserve
    bm.allocate(2, 8, respect_watermark=False)
    assert bm.free_blocks == 0


def test_extend_refusal_mutates_nothing():
    bm = BlockManager(num_blocks=4, block_size=4)
    bm.allocate(1, 4)
    assert not bm.extend(1, 100)
    assert bm.seq_tokens(1) == 4 and bm.free_blocks == 3
