"""RWT estimator (paper §6 + Appendix A.1): closed-form checks, CLT
accuracy-vs-queue-size property (Fig. 18), conservativeness for short
queues (§9)."""
import math

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests only
from hypothesis import given, settings, strategies as st

from repro.core.rwt_estimator import (HardwareProfile, RWTEstimator,
                                      WorkloadProfile)

HW = HardwareProfile(prefill_time=0.2, decode_per_token=0.04,
                     inefficiency=1.2, token_capacity=60_000,
                     swap_time=2.0, model_max_tokens=512)
WL = WorkloadProfile(mu_input=45.0, sigma_input=30.0,
                     mu_output=160.0, sigma_output=80.0)


def test_throughput_formula():
    # Eq. 16: B = GPU / E[I+O];  Eq. 15: Θ = B / (d ε)
    B = 60_000 / (45 + 160)
    theta = B / (0.04 * 1.2)
    assert math.isclose(HW.throughput(WL), theta, rel_tol=1e-9)


def test_waiting_time_linear_in_queue_position():
    est = RWTEstimator()
    w1 = est.waiting_time(10, WL, HW)
    w2 = est.waiting_time(20, WL, HW)
    assert math.isclose(w2.mean, 2 * w1.mean, rel_tol=1e-9)
    # std grows as sqrt(q) (Eq. 3)
    assert math.isclose(w2.std, math.sqrt(2) * w1.std, rel_tol=1e-9)


def test_completion_adds_prefill_and_conservative_decode():
    est = RWTEstimator()
    c = est.request_completion(0, WL, HW)
    assert math.isclose(c.mean, 0.2 + 512 * 1.2 * 0.04, rel_tol=1e-9)


def _simulate_queue_waits(n_requests, rng, batch=None):
    """Token-granular single-instance FCFS continuous batching — ground
    truth the estimator is judged against."""
    outs = np.clip(rng.lognormal(math.log(WL.mu_output) - 0.125, 0.5,
                                 n_requests), 1, 2048).astype(int)
    ins = np.full(n_requests, WL.mu_input)
    B = int(HW.token_capacity / (WL.mu_input + WL.mu_output)) if batch is None else batch
    d = HW.decode_per_token
    t = 0.0
    waits = np.zeros(n_requests)
    running = []  # remaining outputs
    next_idx = 0
    while next_idx < n_requests or running:
        while next_idx < n_requests and len(running) < B:
            waits[next_idx] = t
            running.append(outs[next_idx])
            next_idx += 1
        t += d
        running = [r - 1 for r in running if r > 1]
    return waits, outs


def test_accuracy_improves_with_queue_size():
    """Fig. 18: R² of the waiting-time estimate rises with queue length."""
    est = RWTEstimator(z_conservative=0.0)
    rng = np.random.default_rng(0)
    wl = WorkloadProfile(WL.mu_input, 0.0, float(np.mean(
        np.clip(rng.lognormal(math.log(WL.mu_output) - 0.125, 0.5, 50_000),
                1, 2048))), 1.0)
    waits, _ = _simulate_queue_waits(4000, rng)
    theta = HW.throughput(wl) * HW.inefficiency  # sim has no ε overhead
    preds = np.array([q * wl.mu_output / theta for q in range(4000)])
    r2_small = RWTEstimator.r_squared(preds[:40], waits[:40])
    r2_large = RWTEstimator.r_squared(preds, waits)
    assert r2_large > 0.95, r2_large
    assert r2_large >= r2_small - 1e-9


def test_conservative_for_small_queues():
    """§9(a): small queues => estimate >= actual (SLO-safe)."""
    est = RWTEstimator(z_conservative=1.0)
    rng = np.random.default_rng(1)
    waits, _ = _simulate_queue_waits(64, rng)
    for q in (1, 4, 8, 16):
        c = est.request_completion(q, WL, HW)
        assert c.conservative() + 1e-9 >= waits[q], (q, c.conservative(), waits[q])


@settings(max_examples=40, deadline=None)
@given(q=st.integers(0, 10_000),
       mu=st.floats(1, 2000), sigma=st.floats(0, 500),
       cap=st.integers(1000, 200_000))
def test_estimator_invariants(q, mu, sigma, cap):
    est = RWTEstimator()
    wl = WorkloadProfile(50.0, 10.0, mu, sigma)
    hw = HardwareProfile(0.1, 0.05, 1.2, cap)
    w = est.waiting_time(q, wl, hw)
    assert w.mean >= 0 and w.std >= 0
    # monotone in queue position
    w2 = est.waiting_time(q + 1, wl, hw)
    assert w2.mean >= w.mean
    # group drain scales with n
    g1 = est.group_drain_time(10, wl, hw)
    g2 = est.group_drain_time(20, wl, hw)
    assert g2.mean >= g1.mean


def test_r_squared_perfect_and_bad():
    assert RWTEstimator.r_squared([1, 2, 3], [1, 2, 3]) == pytest.approx(1.0)
    assert RWTEstimator.r_squared([3, 3, 3], [1, 2, 6]) < 0.5
