"""Fused paged prefill-chunk kernel + multi-page decode tile parity.

The fused kernel (``kernels/paged_prefill_attention.py``) must match the
XLA gather oracle (the path ``attend_prefill_chunk_paged`` falls back to)
bit-for-bit up to float tolerance on every VALID query row, across the
chunk-boundary shapes the engine produces: a chunk whose start straddles a
page edge, ``valid == 0`` inactive rows, the first chunk of a prompt
(empty page prefix), and a final partial chunk.  Rows past ``valid`` are
garbage in BOTH paths and excluded (callers ignore them).

The decode half: multi-page kv tiles (``pages_per_tile`` > 1) must be a
pure perf reshaping — identical outputs at small block sizes with ragged
per-sequence ``kv_valid``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

# kernel-executing tests carry the `pallas` marker individually; the pure
# XLA oracle/gather tests stay unmarked so `-m "not pallas"` keeps them


def _mk_paged_prefill_case(rng, *, B, H, KVH, C, D, bs, nb, starts, valid):
    """Random page pool (unowned pages hold garbage on purpose), permuted
    block tables, chunk q/k/v, plus a densified prefix for the from-scratch
    oracle."""
    N = 4 * B * nb
    q = rng.standard_normal((B, H, C, D)).astype(np.float32)
    kp = rng.standard_normal((N, KVH, bs, D)).astype(np.float32)
    vp = rng.standard_normal((N, KVH, bs, D)).astype(np.float32)
    ck = rng.standard_normal((B, KVH, C, D)).astype(np.float32)
    cv = rng.standard_normal((B, KVH, C, D)).astype(np.float32)
    bt = rng.permutation(N)[:B * nb].reshape(B, nb).astype(np.int32)
    return q, kp, vp, ck, cv, bt, np.asarray(starts, np.int32), \
        np.asarray(valid, np.int32)


def _assert_valid_rows_close(out, want, valid, **tol):
    """Compare only rows < valid[b] (garbage rows differ by design)."""
    for b, n in enumerate(valid):
        if n > 0:
            np.testing.assert_allclose(np.asarray(out[b, :, :n], np.float32),
                                       np.asarray(want[b, :, :n], np.float32),
                                       **tol)


# ---------------------------------------------------------------------------
# fused paged prefill-chunk kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pages_per_tile", [None, 1, 2])
@pytest.mark.parametrize("bs", [8, 16])
@pytest.mark.pallas
def test_paged_prefill_parity_across_chunk_boundaries(bs, pages_per_tile):
    """Float kernel == gather oracle for: first chunk (empty prefix), a
    prefix ending mid-page (chunk start straddles a page edge), a
    page-aligned prefix, an inactive row, and a final partial chunk."""
    rng = np.random.default_rng(20)
    C, nb = 16, 6
    starts = [0, 19 if bs == 8 else 21, 2 * bs, 11, 0]
    valid = [C, C, 5, 0, 3]          # full / full / partial / inactive / part
    q, kp, vp, ck, cv, bt, st, vd = _mk_paged_prefill_case(
        rng, B=5, H=4, KVH=2, C=C, D=32, bs=bs, nb=nb,
        starts=starts, valid=valid)
    out = ops.paged_prefill_attention(q, kp, vp, ck, cv, bt, st, vd,
                                      pages_per_tile=pages_per_tile)
    want = ref.paged_prefill_attention_ref(jnp.asarray(q), kp, vp, ck, cv,
                                           bt, st, vd)
    _assert_valid_rows_close(out, want, valid, rtol=2e-5, atol=2e-5)


@pytest.mark.pallas
def test_paged_prefill_oracle_matches_dense_from_scratch():
    """The gather oracle itself cross-checked against plain full causal
    attention over [prefix ; chunk]: chunk row c == full-sequence row
    start + c when the chunk completes the prompt."""
    rng = np.random.default_rng(21)
    B, H, KVH, C, D, bs, nb = 1, 4, 2, 8, 16, 8, 4
    start = 13                      # straddles a page edge
    L = start + C
    k_full = rng.standard_normal((B, KVH, L, D)).astype(np.float32)
    v_full = rng.standard_normal((B, KVH, L, D)).astype(np.float32)
    q_full = rng.standard_normal((B, H, L, D)).astype(np.float32)

    # scatter the prefix into a page pool
    N = 8
    kp = rng.standard_normal((N, KVH, bs, D)).astype(np.float32)
    vp = rng.standard_normal((N, KVH, bs, D)).astype(np.float32)
    bt = rng.permutation(N)[:nb].reshape(1, nb).astype(np.int32)
    for p in range(start):
        kp[bt[0, p // bs], :, p % bs] = k_full[0, :, p]
        vp[bt[0, p // bs], :, p % bs] = v_full[0, :, p]

    q = q_full[:, :, start:]
    ck = k_full[:, :, start:]
    cv = v_full[:, :, start:]
    st = np.array([start], np.int32)
    vd = np.array([C], np.int32)

    full = ref.flash_attention_ref(jnp.asarray(q_full), k_full, v_full,
                                   causal=True)[:, :, start:]
    for fn in (ref.paged_prefill_attention_ref, ops.paged_prefill_attention):
        got = fn(jnp.asarray(q), kp, vp, ck, cv, bt, st, vd)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(full, np.float32),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("pages_per_tile", [None, 2])
@pytest.mark.pallas
def test_paged_prefill_quant_parity(pages_per_tile):
    """int8 page pool + per-row scale pages (prefix dequantized in VMEM,
    in-chunk k/v float) == the quant gather oracle."""
    rng = np.random.default_rng(22)
    B, H, KVH, C, D, bs, nb = 3, 4, 2, 16, 32, 8, 6
    N = 30
    q = rng.standard_normal((B, H, C, D)).astype(np.float32)
    kq = rng.integers(-127, 128, size=(N, KVH, bs, D)).astype(np.int8)
    vq = rng.integers(-127, 128, size=(N, KVH, bs, D)).astype(np.int8)
    ks = (rng.random((N, KVH, bs)) * 0.1).astype(np.float32)
    vs = (rng.random((N, KVH, bs)) * 0.1).astype(np.float32)
    ck = rng.standard_normal((B, KVH, C, D)).astype(np.float32)
    cv = rng.standard_normal((B, KVH, C, D)).astype(np.float32)
    bt = rng.permutation(N)[:B * nb].reshape(B, nb).astype(np.int32)
    starts = np.array([0, 19, 48], np.int32)   # empty / mid-page / aligned
    valid = np.array([16, 7, 0], np.int32)
    out = ops.paged_prefill_attention_quant(q, kq, vq, ks, vs, ck, cv, bt,
                                            starts, valid,
                                            pages_per_tile=pages_per_tile)
    want = ref.paged_prefill_attention_quant_ref(jnp.asarray(q), kq, vq, ks,
                                                 vs, ck, cv, bt, starts, valid)
    _assert_valid_rows_close(out, want, valid, rtol=2e-4, atol=2e-4)


@pytest.mark.pallas
def test_paged_prefill_sentinel_blocks_ignored():
    """Logical blocks at/past the prefix may hold sentinel (out-of-pool)
    ids — required by the engine, whose tables are sentinel-padded."""
    rng = np.random.default_rng(23)
    B, H, KVH, C, D, bs, nb = 1, 2, 2, 8, 16, 8, 4
    q, kp, vp, ck, cv, bt, st, vd = _mk_paged_prefill_case(
        rng, B=B, H=H, KVH=KVH, C=C, D=D, bs=bs, nb=nb,
        starts=[11], valid=[C])
    out1 = ops.paged_prefill_attention(q, kp, vp, ck, cv, bt, st, vd)
    bt_sent = bt.copy()
    bt_sent[0, 2:] = kp.shape[0] + 7      # sentinel >= pool size
    out2 = ops.paged_prefill_attention(q, kp, vp, ck, cv, bt_sent, st, vd)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


# ---------------------------------------------------------------------------
# q-tiling: chunks wider than one q tile split across grid steps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [256, 512])
@pytest.mark.pallas
def test_paged_prefill_q_tiled_long_chunk_parity(chunk):
    """Chunks past one q tile (prefill_chunk_tokens=512+) split across the
    q grid dimension (auto_q_tile -> 128 rows) and must match the gather
    oracle on every valid row — heterogeneous starts/valid, block_size 8,
    a ragged row ending mid-tile, and an inactive row."""
    from repro.kernels.paged_prefill_attention import auto_q_tile
    assert auto_q_tile(chunk) == 128          # > 1 q tile per chunk
    rng = np.random.default_rng(30)
    bs = 8
    nb = (40 + chunk + bs - 1) // bs + 1
    starts = [40, 7, 0]
    valid = [chunk, chunk - 77, 0]            # full / mid-tile ragged / dead
    q, kp, vp, ck, cv, bt, st, vd = _mk_paged_prefill_case(
        rng, B=3, H=4, KVH=2, C=chunk, D=32, bs=bs, nb=nb,
        starts=starts, valid=valid)
    out = ops.paged_prefill_attention(q, kp, vp, ck, cv, bt, st, vd)
    want = ref.paged_prefill_attention_ref(jnp.asarray(q), kp, vp, ck, cv,
                                           bt, st, vd)
    _assert_valid_rows_close(out, want, valid, rtol=2e-5, atol=2e-5)


@pytest.mark.pallas
def test_paged_prefill_explicit_q_tile_matches_single_tile():
    """q_tile is a pure tiling choice: explicit narrow tiles == the
    one-tile layout bit-for-bit on valid rows (float and int8 twins)."""
    rng = np.random.default_rng(31)
    B, H, KVH, C, D, bs, nb = 2, 4, 2, 64, 32, 8, 12
    starts, valid = [19, 0], [C, C - 5]
    q, kp, vp, ck, cv, bt, st, vd = _mk_paged_prefill_case(
        rng, B=B, H=H, KVH=KVH, C=C, D=D, bs=bs, nb=nb,
        starts=starts, valid=valid)
    base = ops.paged_prefill_attention(q, kp, vp, ck, cv, bt, st, vd,
                                       q_tile=C)
    for qt in (16, 32):
        tiled = ops.paged_prefill_attention(q, kp, vp, ck, cv, bt, st, vd,
                                            q_tile=qt)
        _assert_valid_rows_close(tiled, base, valid, rtol=1e-6, atol=1e-6)

    N = kp.shape[0]
    ks = (rng.random((N, KVH, bs)) * 0.1).astype(np.float32)
    vs = (rng.random((N, KVH, bs)) * 0.1).astype(np.float32)
    kq = rng.integers(-127, 128, size=(N, KVH, bs, D)).astype(np.int8)
    vq = rng.integers(-127, 128, size=(N, KVH, bs, D)).astype(np.int8)
    qbase = ops.paged_prefill_attention_quant(q, kq, vq, ks, vs, ck, cv, bt,
                                              st, vd, q_tile=C)
    qtiled = ops.paged_prefill_attention_quant(q, kq, vq, ks, vs, ck, cv, bt,
                                               st, vd, q_tile=16)
    _assert_valid_rows_close(qtiled, qbase, valid, rtol=1e-6, atol=1e-6)


@pytest.mark.pallas
def test_engine_long_chunk_q_tiled_token_parity():
    """End-to-end: a paged-pallas engine at prefill_chunk_tokens=256 (the
    q-tiled kernel path, bucket 256 > one 128-row tile) produces the same
    tokens as the dense xla backend for a long prompt."""
    from repro.configs import ARCHITECTURES
    from repro.core.request import Request
    from repro.models import build_model
    from repro.serving import ContinuousBatchingEngine, EngineConfig

    cfg = ARCHITECTURES["granite-3-2b"].reduced(num_layers=1, d_model=64)
    model_ = build_model(cfg)
    params = model_.init(jax.random.key(0))
    rng = np.random.default_rng(32)
    prompts = [rng.integers(0, 100, size=n).tolist() for n in (300, 9)]

    def run(backend):
        eng = ContinuousBatchingEngine(
            model_, params,
            EngineConfig(max_slots=2, max_seq_len=384, block_size=8,
                         prefill_chunk_tokens=256,
                         attention_backend=backend),
            model_name="m1")
        reqs = [Request(prompt_tokens=p, model="m1", slo=1e9,
                        max_new_tokens=3) for p in prompts]
        for r in reqs:
            assert eng.admit(r)
        for _ in range(40):
            eng.step()
            if all(r.finished() for r in reqs):
                break
        assert all(r.finished() for r in reqs)
        return [r.output_tokens for r in reqs]

    assert run("paged-pallas") == run("xla")


# ---------------------------------------------------------------------------
# multi-page decode tiles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bs", [8, 16])
@pytest.mark.parametrize("pages_per_tile", [1, 2, 4, None])
@pytest.mark.pallas
def test_paged_decode_multi_page_tiles(bs, pages_per_tile):
    """pages_per_tile is a pure perf reshaping: identical outputs for
    ragged kv_valid (1 token / mid-page / full pool) at small block
    sizes."""
    rng = np.random.default_rng(24)
    B, H, KVH, S, D = 3, 8, 2, 64, 32
    nb = S // bs
    q = rng.standard_normal((B, H, D)).astype(np.float32)
    k = rng.standard_normal((B, KVH, S, D)).astype(np.float32)
    v = rng.standard_normal((B, KVH, S, D)).astype(np.float32)
    N = 4 * B * nb
    perm = rng.permutation(N)[:B * nb].reshape(B, nb)
    kp = rng.standard_normal((N, KVH, bs, D)).astype(np.float32)
    vp = rng.standard_normal((N, KVH, bs, D)).astype(np.float32)
    for b in range(B):
        for i in range(nb):
            kp[perm[b, i]] = k[b, :, i * bs:(i + 1) * bs]
            vp[perm[b, i]] = v[b, :, i * bs:(i + 1) * bs]
    kv_valid = np.array([1, bs + 3, S], np.int32)   # ragged
    out = ops.paged_decode_attention(q, kp, vp, perm.astype(np.int32),
                                     kv_valid, pages_per_tile=pages_per_tile)
    want = ref.decode_attention_ref(jnp.asarray(q), k, v, kv_valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.pallas
def test_paged_decode_quant_multi_page_tiles():
    """int8 twin with pages_per_tile > 1 == dequantized oracle."""
    rng = np.random.default_rng(25)
    B, H, KVH, S, D, bs = 2, 4, 2, 48, 32, 8
    nb, N = S // bs, 24
    q = rng.standard_normal((B, H, D)).astype(np.float32)
    kq = rng.integers(-127, 128, size=(N, KVH, bs, D)).astype(np.int8)
    vq = rng.integers(-127, 128, size=(N, KVH, bs, D)).astype(np.int8)
    ks = (rng.random((N, KVH, bs)) * 0.1).astype(np.float32)
    vs = (rng.random((N, KVH, bs)) * 0.1).astype(np.float32)
    bt = rng.permutation(N)[:B * nb].reshape(B, nb).astype(np.int32)
    lengths = np.array([S, 13], np.int32)
    from repro.kernels.paged_decode_attention import gather_kv_pages_fused
    kd, vd = gather_kv_pages_fused(jnp.asarray(kq), jnp.asarray(vq),
                                   jnp.asarray(bt))
    ksd, vsd = gather_kv_pages_fused(jnp.asarray(ks), jnp.asarray(vs),
                                     jnp.asarray(bt))
    k = np.asarray(kd, np.float32) * np.asarray(ksd)[..., None]
    v = np.asarray(vd, np.float32) * np.asarray(vsd)[..., None]
    want = ref.decode_attention_ref(jnp.asarray(q), k, v, lengths)
    for P in (2, 3):
        out = ops.paged_decode_attention_quant(q, kq, vq, ks, vs, bt,
                                               lengths, pages_per_tile=P)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


def test_gather_kv_pages_fused_matches_single():
    """The stacked (fused) gather == two independent gathers, value and
    scale shapes, sentinel entries included."""
    from repro.kernels.paged_decode_attention import (gather_kv_pages,
                                                     gather_kv_pages_fused)
    rng = np.random.default_rng(26)
    N, KVH, bs, D = 10, 2, 8, 16
    kp = rng.standard_normal((N, KVH, bs, D)).astype(np.float32)
    vp = rng.standard_normal((N, KVH, bs, D)).astype(np.float32)
    sp = rng.standard_normal((N, KVH, bs)).astype(np.float32)
    tp = rng.standard_normal((N, KVH, bs)).astype(np.float32)
    bt = np.array([[0, 3, N + 5], [7, 1, 2]], np.int32)  # incl. sentinel
    for a, b in ((kp, vp), (sp, tp)):
        fa, fb = gather_kv_pages_fused(jnp.asarray(a), jnp.asarray(b),
                                       jnp.asarray(bt))
        np.testing.assert_array_equal(np.asarray(fa),
                                      np.asarray(gather_kv_pages(
                                          jnp.asarray(a), jnp.asarray(bt))))
        np.testing.assert_array_equal(np.asarray(fb),
                                      np.asarray(gather_kv_pages(
                                          jnp.asarray(b), jnp.asarray(bt))))


# ---------------------------------------------------------------------------
# engine-level: explicit pages_per_tile stays token-identical
# ---------------------------------------------------------------------------

@pytest.mark.pallas
def test_engine_pages_per_tile_token_parity():
    """EngineConfig.pages_per_tile (multi-page kv tiles in BOTH paged
    kernels) must not change a single token vs the default."""
    from repro.configs import ARCHITECTURES
    from repro.core.request import Request
    from repro.serving import ContinuousBatchingEngine, EngineConfig

    cfg = ARCHITECTURES["granite-3-2b"].reduced(num_layers=1, d_model=64)
    model_ = __import__("repro.models", fromlist=["build_model"]) \
        .build_model(cfg)
    params = model_.init(jax.random.key(0))
    rng = np.random.default_rng(27)
    prompts = [rng.integers(0, 100, size=n).tolist() for n in (3, 21)]

    def run(pages_per_tile):
        eng = ContinuousBatchingEngine(
            model_, params,
            EngineConfig(max_slots=2, max_seq_len=64, block_size=8,
                         prefill_chunk_tokens=16,
                         attention_backend="paged-pallas",
                         pages_per_tile=pages_per_tile),
            model_name="m1")
        reqs = [Request(prompt_tokens=p, model="m1", slo=1e9,
                        max_new_tokens=4) for p in prompts]
        for r in reqs:
            assert eng.admit(r)
        for _ in range(40):
            eng.step()
            if all(r.finished() for r in reqs):
                break
        assert all(r.finished() for r in reqs)
        assert eng.model.cfg.paged_pages_per_tile == pages_per_tile
        return [r.output_tokens for r in reqs]

    assert run(None) == run(2) == run(1)
