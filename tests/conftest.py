import os

# Tests run on the single real CPU device (the 512-device override is ONLY
# for the dry-run, set inside repro.launch.dryrun before jax import).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "pallas: Pallas-kernel parity tests (interpret mode off-TPU) — "
        "select with `-m pallas`, skip with `-m 'not pallas'`")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)
