import os

# Tests run on the single real CPU device (the 512-device override is ONLY
# for the dry-run, set inside repro.launch.dryrun before jax import).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "pallas: Pallas-kernel parity tests (interpret mode off-TPU) — "
        "select with `-m pallas`, skip with `-m 'not pallas'`")
    # QLINT_INVARIANTS=1 turns the whole suite into an invariant suite:
    # every BlockManager state transition and every engine round boundary
    # (in ANY test, however the engine was constructed) runs
    # repro.analysis.invariants checks.  QLINT_INVARIANTS_SAMPLE=N keeps
    # it cheap on long property tests.
    from repro.analysis.invariants import install_test_hooks, \
        invariants_enabled
    if invariants_enabled():
        install_test_hooks()


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)
