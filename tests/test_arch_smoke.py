"""Per-architecture smoke tests (deliverable f): REDUCED variant of each
family runs one forward/train step AND one prefill+decode step on CPU,
asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.models import build_model, materialize_batch
from repro.training import AdamW, make_train_step

ARCHS = sorted(ARCHITECTURES)


@pytest.mark.parametrize("name", ARCHS)
def test_train_step(name):
    cfg = ARCHITECTURES[name].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = materialize_batch(cfg, 2, 24, "train", jax.random.key(1))
    assert batch["tokens"].shape == (2, 25)
    opt = AdamW(learning_rate=1e-3)
    step = jax.jit(make_train_step(model, opt))
    new_params, _, metrics = step(params, opt.init(params), batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 0.0 < loss < 20.0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda p, q: float(jnp.abs(p - q).sum()), params, new_params))
    assert delta > 0


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_shapes(name):
    cfg = ARCHITECTURES[name].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, L, MAX = 2, 12, 32
    batch = materialize_batch(cfg, B, L, "prefill", jax.random.key(1))
    cache = model.init_cache(B, MAX)
    logits, cache = model.prefill(params, batch, cache)
    assert logits.shape[0] == B and logits.shape[-1] >= cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits)))
    plen = batch["tokens"].shape[1]
    if cfg.vision is not None:
        plen += cfg.vision.num_patch_tokens
    lengths = jnp.full((B,), plen, jnp.int32)
    tokens = jnp.argmax(logits, -1).astype(jnp.int32)
    assert int(tokens.max()) < cfg.vocab_size, "padded-vocab logits must be masked"
    for _ in range(2):
        logits, cache = model.decode_step(params, cache, tokens, lengths)
        tokens = jnp.argmax(logits, -1).astype(jnp.int32)
        lengths = lengths + 1
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(tokens.max()) < cfg.vocab_size


@pytest.mark.parametrize("name", ARCHS)
def test_param_axes_tree_matches_params(name):
    """Every param leaf must have a logical-axes annotation (right-aligned)."""
    cfg = ARCHITECTURES[name].reduced()
    model = build_model(cfg)
    params_struct = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    axes = model.param_axes()
    pl = jax.tree_util.tree_leaves(params_struct)
    al = jax.tree_util.tree_leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(pl) == len(al)
    for leaf, ax in zip(pl, al):
        assert len(ax) <= len(leaf.shape), (name, leaf.shape, ax)


@pytest.mark.parametrize("name", ARCHS)
def test_cache_axes_tree_matches_cache(name):
    cfg = ARCHITECTURES[name].reduced()
    model = build_model(cfg)
    cache_struct = jax.eval_shape(lambda: model.init_cache(2, 16))
    axes = model.cache_axes()
    cl = jax.tree_util.tree_leaves(cache_struct)
    al = jax.tree_util.tree_leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(cl) == len(al)
    for leaf, ax in zip(cl, al):
        assert len(ax) <= len(leaf.shape), (name, leaf.shape, ax)
