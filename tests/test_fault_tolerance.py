"""Fault-tolerance tests: seeded fault injection (serving/faults), the
controller supervision layer (health states, heartbeats, mark_dead),
request redelivery with backoff + poison quarantine, pinned-snapshot
discard on owner death, and the end-to-end chaos soak (launch/chaos).

The pyramid: unit tests drive the supervision machinery against stub
instances/engines (fast, exact); the two soak tests at the bottom run
the real JAX engines under a seeded kill and assert the recovery
contract — plus its converse: with supervision off the same plan
demonstrably strands requests.
"""
import argparse

import pytest

from repro.analysis.invariants import (InvariantViolation,
                                       check_block_manager,
                                       check_terminal_states)
from repro.core.global_scheduler import InstanceInfo
from repro.core.lso import QLMAgent
from repro.core.qlm import (DEAD, DEGRADED, DRAINED, DRAINING, HEALTHY,
                            QLMConfig, QLMController)
from repro.core.request import make_request
from repro.core.rwt_estimator import HardwareProfile
from repro.core.virtual_queue import VirtualQueue
from repro.serving.faults import (EngineCrashed, EngineDead, FaultPlan,
                                  FaultSpec, TransientEngineError)
from repro.serving.kv_cache import BlockManager


def _hw(**kw):
    base = dict(prefill_time=0.05, decode_per_token=0.02, inefficiency=1.2,
                token_capacity=512, swap_time=0.2, model_max_tokens=32)
    base.update(kw)
    return HardwareProfile(**base)


def _instance(iid, models, current=None):
    return InstanceInfo(iid, {m: _hw() for m in models}, current,
                        VirtualQueue(iid))


def _controller(instances, **cfg):
    cfg.setdefault("avg_batch_size", 4)
    cfg.setdefault("reschedule_on_arrival", False)
    return QLMController(instances, QLMConfig(**cfg))


class _StubStats:
    """Mutable counter bag matching QLMController._progress_marker."""
    tokens_generated = 0
    prefills = 0
    prefill_chunks = 0
    evictions = 0
    resumes = 0
    model_swaps = 0
    cancellations = 0


class _StubEngine:
    """Just enough engine surface for mark_dead / QLMAgent / watchdog
    plumbing."""

    def __init__(self, resident=(), block_mgr=None):
        self.resident = list(resident)
        self.block_mgr = block_mgr
        self.slots = []
        self._pushback = None
        self.pull_source = None
        self.stats = _StubStats()

    def num_active(self):
        return len(self.resident)

    def abandon(self):
        out, self.resident = self.resident, []
        for r in out:
            r._in_flight = False
        return out

    def take_pushback(self):
        p, self._pushback = self._pushback, None
        return p

    def step(self):
        return []

    def steps(self, n=1):
        return []

    def prefilling_slots(self):
        return []

    def decode_slots(self):
        return []

    def swap_model(self, *a, **kw):
        return []

    def _materialize_pinned_snapshots(self):
        pass


# ---------------------------------------------------------------------------
# FaultPlan: seeded determinism
# ---------------------------------------------------------------------------

def _drive(plan, rounds=40):
    fired = []
    for _ in range(rounds):
        for eng in (0, 1):
            for site in ("round", "decode"):
                spec = plan.fire(eng, site)
                if spec is not None:
                    fired.append((eng, site, spec.kind))
    return fired


def test_fault_plan_replays_identically_from_seed():
    specs = [FaultSpec("decode", "error", prob=0.15, max_fires=3),
             FaultSpec("round", "crash", engine=1, at_count=7),
             FaultSpec("decode", "error", prob=0.3, max_fires=2)]
    plan = FaultPlan(specs, seed=42)
    first = _drive(plan)
    assert first, "plan never fired — test is vacuous"
    assert (1, "round", "crash") in first
    # same seed, fresh state -> identical firing sequence AND timeline
    replay = plan.fresh()
    assert _drive(replay) == first
    assert replay.timeline() == plan.timeline()
    # a different seed diverges somewhere (probabilistic specs redraw)
    other = FaultPlan(specs, seed=43)
    assert _drive(other) != first


def test_fault_plan_per_spec_rng_isolation():
    """Removing one probabilistic spec must not shift another spec's
    draw sequence (per-spec RNGs, not one shared stream)."""
    a = FaultSpec("decode", "error", prob=0.2, max_fires=100)
    b = FaultSpec("round", "error", prob=0.2, max_fires=100)
    both = FaultPlan([a, b], seed=7)
    only_b_events = [e for e in (_drive(both), both.events)[1]
                     if e["spec"] == 1]
    solo = FaultPlan([b], seed=7)
    # spec b sits at a different index in the solo plan, so reseed it the
    # way the plan does: index 1 in `both`
    solo._rngs[0] = type(solo._rngs[0])((7 << 8) ^ 1)
    _drive(solo)
    assert [(e["engine"], e["occurrence"]) for e in solo.events] \
        == [(e["engine"], e["occurrence"]) for e in only_b_events]


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec("nope", "crash", at_count=1)
    with pytest.raises(ValueError):
        FaultSpec("decode", "meltdown", at_count=1)
    with pytest.raises(ValueError):
        FaultSpec("decode", "crash")          # neither at_count nor prob


def test_crashed_engine_stays_dead():
    plan = FaultPlan([FaultSpec("round", "crash", at_count=1)], seed=0)
    from repro.serving.faults import FaultyEngine
    eng = FaultyEngine(_StubEngine(), plan, engine_id=0)
    with pytest.raises(EngineCrashed):
        eng.step()
    assert eng.dead
    with pytest.raises(EngineDead):
        eng.step()
    assert eng.cancel_request(object()) is False


# ---------------------------------------------------------------------------
# backoff math
# ---------------------------------------------------------------------------

def test_backoff_monotone_and_capped():
    c = _controller([_instance(0, ["m"])],
                    backoff_base_s=0.1, backoff_cap_s=1.0)
    seq = [c.backoff(n) for n in range(1, 10)]
    assert seq[0] == pytest.approx(0.1)
    assert seq[1] == pytest.approx(0.2)
    assert all(b2 >= b1 for b1, b2 in zip(seq, seq[1:]))
    assert seq[-1] == 1.0 and max(seq) == 1.0


def test_backoff_gates_fcfs_pull():
    """A redelivered request is invisible to pulls until not_before."""
    inst = _instance(0, ["m"])
    c = _controller([inst])
    r = make_request([1, 2], "m", "batch1", arrival_time=0.0)
    assert c.submit(r, 0.0)
    r.not_before = 5.0
    assert inst.virtual_queue.next_request("m", now=1.0) is None
    assert inst.virtual_queue.next_request("m", now=5.0) is r


# ---------------------------------------------------------------------------
# health state machine
# ---------------------------------------------------------------------------

def test_transient_strikes_then_death_and_heartbeat_recovery():
    c = _controller([_instance(0, ["m"])], transient_strikes=3)
    e = TransientEngineError("flaky")
    assert c.report_engine_failure(0, e, 1.0) == DEGRADED
    assert c.health[0].strikes == 1
    # a good iteration heals the strike counter and the state
    c.heartbeat(0, 2.0)
    assert c.health[0].state == HEALTHY and c.health[0].strikes == 0
    # three consecutive strikes without a heartbeat give up on it
    assert c.report_engine_failure(0, e, 3.0) == DEGRADED
    assert c.report_engine_failure(0, e, 4.0) == DEGRADED
    assert c.report_engine_failure(0, e, 5.0) == DEAD
    assert not c.is_alive(0)
    # dead is terminal: neither heartbeats nor further reports revive it
    c.heartbeat(0, 6.0)
    assert c.report_engine_failure(0, e, 7.0) == DEAD


def test_fatal_exception_kills_immediately():
    c = _controller([_instance(0, ["m"]), _instance(1, ["m"])])
    assert c.report_engine_failure(1, EngineCrashed("boom"), 1.0) == DEAD
    assert c.health[1].cause and "boom" in c.health[1].cause
    assert [c.is_alive(0), c.is_alive(1)] == [True, False]
    assert len(c.alive_instances()) == 1 and c.alive_fraction() == 0.5


def test_heartbeat_timeout_degrades_then_kills():
    c = _controller([_instance(0, ["m"])], heartbeat_timeout_s=1.0,
                    degraded_after_missed=1, dead_after_missed=3)
    c.check_heartbeats(10.0)          # first sight: starts the window
    assert c.health[0].state == HEALTHY
    c.check_heartbeats(11.5)          # 1 missed window
    assert c.health[0].state == DEGRADED
    c.heartbeat(0, 11.6)              # sign of life: recover
    assert c.health[0].state == HEALTHY
    c.check_heartbeats(14.7)          # 3 windows since 11.6
    assert c.health[0].state == DEAD
    assert "heartbeat" in c.health[0].cause


# ---------------------------------------------------------------------------
# mark_dead: redelivery, exclusion, quarantine
# ---------------------------------------------------------------------------

def test_mark_dead_redelivers_resident_requests():
    a, b = _instance(0, ["m"]), _instance(1, ["m"])
    c = _controller([a, b], retry_budget=2, backoff_base_s=0.5)
    t0 = 1.0
    r = make_request([1, 2, 3], "m", "batch1", arrival_time=t0)
    assert c.submit(r, t0)
    # simulate instance 1 having pulled it
    r._in_flight = True
    r._served_by = 1
    eng = _StubEngine(resident=[r])
    c.mark_dead(1, 5.0, cause="test-kill", engine=eng)

    assert not c.is_alive(1)
    assert not b.virtual_queue.groups            # dead VQ emptied
    assert r in c.global_queue and not r.finished()
    assert not r._in_flight and r._served_by is None
    assert r.redeliveries == 1 and c.redeliveries == 1
    assert r.not_before == pytest.approx(5.0 + 0.5)
    # the group is reachable again from the survivor
    assert any(r in g.requests for g in a.virtual_queue.groups)
    # and the survivor can actually hand it out once backoff expires
    assert a.virtual_queue.next_request("m", now=6.0) is r


def test_retry_budget_exhaustion_quarantines_as_miss():
    inst = _instance(0, ["m"])
    c = _controller([inst], retry_budget=2)
    t0 = 0.0
    r = make_request([1, 2], "m", "interactive", arrival_time=t0)
    assert c.submit(r, t0)
    for n in (1, 2):
        c._redeliver(r, float(n))
        assert r.redeliveries == n and not r.failed
    c._redeliver(r, 3.0)                         # third death: poison
    assert r.failed and r.dropped() and r.finished()
    assert "retry budget" in r.fail_cause
    assert r in c.failed and r.completion_time == 3.0
    c.gc_groups()
    assert r in c.finished
    # an unconditional miss, even with a pre-crash first token in time
    r.first_token_time = t0 + 0.1
    assert c.slo_attainment(4.0) < 1.0


def test_mark_dead_quarantines_unservable_models():
    a, b = _instance(0, ["m1"]), _instance(1, ["m2"])
    c = _controller([a, b])
    r = make_request([1, 2], "m2", "batch1", arrival_time=0.0)
    assert c.submit(r, 0.0)
    c.mark_dead(1, 1.0, cause="only m2 server dies")
    assert r.failed and "unservable" in r.fail_cause
    assert r in c.failed
    # the controller now refuses new m2 work at the gate
    r2 = make_request([3], "m2", "batch1", arrival_time=2.0)
    assert c.submit(r2, 2.0) is False and r2.rejected


def test_mark_dead_discards_snapshots_pinned_in_dead_pool():
    """A request evicted WITH pinned prefix blocks in the dead engine's
    pool: the pins are released (dead pool conserves) and the request
    restarts cleanly on a survivor — generated tokens wiped, attempt
    accounting intact."""
    bm = BlockManager(16, 4, cache_freed=True)
    bm.attach_slot_table(4, 16)
    bm.allocate(1, 8)
    bm.bind_slot(1, 0)
    bm.register_prefix(1, list(range(8)), 8)
    bm.fork(1, 2)                     # prefix now shared -> evictable pins
    bm.bind_slot(2, 1)
    pinned, _private = bm.evict_split(1)
    assert pinned and bm._pins
    check_block_manager(bm)

    a, b = _instance(0, ["m"]), _instance(1, ["m"])
    c = _controller([a, b])
    t0 = 0.0
    r = make_request(list(range(8)), "m", "batch1", arrival_time=t0)
    assert c.submit(r, t0)
    r.generated = 3
    r.output_tokens.extend([7, 8, 9])
    r.first_token_time = t0 + 0.2
    r.snapshot = {"pinned": pinned, "pin_owner": bm, "pin_epoch": bm.epoch}

    c.mark_dead(1, 1.0, cause="pool dies", engine=_StubEngine(block_mgr=bm))
    assert not bm._pins, "pins must die with the owner"
    bm.free(2)
    assert not bm._seqs
    check_block_manager(bm)
    # clean restart: no half-generated state, no stale snapshot
    assert r.snapshot is None and r.generated == 0 and r.output_tokens == []
    assert r.first_token_time == t0 + 0.2        # kept: no double-count
    assert not r.finished() and r in c.global_queue


def test_agent_reset_clears_head_and_pushback():
    eng = _StubEngine()
    agent = QLMAgent(eng, VirtualQueue(0), {})
    agent._last_head = object()
    limbo = make_request([1], "m", "batch1")
    limbo._in_flight = True
    limbo._served_by = 0
    eng._pushback = limbo
    agent.reset()
    assert agent._last_head is None
    assert eng._pushback is None
    assert not limbo._in_flight and limbo._served_by is None


# ---------------------------------------------------------------------------
# terminal-state conservation
# ---------------------------------------------------------------------------

def test_terminal_states_clean_pass_and_stranded_caught():
    inst = _instance(0, ["m"])
    c = _controller([inst])
    r = make_request([1, 2], "m", "batch1", arrival_time=0.0)
    assert c.submit(r, 0.0)
    check_terminal_states(c)                     # queued + placed: fine

    # in-flight but resident in no alive engine == stranded
    r._in_flight = True
    with pytest.raises(InvariantViolation) as e:
        check_terminal_states(c, engines=[_StubEngine()])
    assert "in-flight" in str(e.value) or "resident" in str(e.value)

    # a failed request must carry a completion stamp (liveness leak)
    r._in_flight = False
    r.failed = True
    c.failed.append(r)
    with pytest.raises(InvariantViolation):
        check_terminal_states(c)
    r.completion_time = 1.0
    check_terminal_states(c)


# ---------------------------------------------------------------------------
# end-to-end: seeded chaos soak on real engines
# ---------------------------------------------------------------------------

def _chaos_args(**over):
    from repro.launch import chaos
    ap_defaults = dict(arch="granite-3-2b", instances=2, requests=10,
                       rate=8.0, max_new_tokens=8, slots=4, seed=0,
                       site="decode", kill_engine=1, kill_at=2,
                       error_prob=0.0, retry_budget=2, round_dt=0.05,
                       max_rounds=600, attainment_floor=0.5,
                       no_supervision=False, replay_check=False,
                       json=None, timeline=None, scenario="kill",
                       plan_file=None, hang_engine=0, hang_at=6,
                       hang_grace=None, drain_engine=None,
                       drain_at_round=None, drain_evict=False,
                       replace_cooldown=0.5, shared_prefix=None)
    ap_defaults.update(over)
    return chaos, argparse.Namespace(**ap_defaults)


def test_chaos_soak_recovers_from_engine_death():
    chaos, args = _chaos_args()
    stats = chaos.run_soak(args)
    assert stats["dead_instances"] == [1]
    assert stats["stranded"] == 0
    assert stats["leaked_blocks"] == []
    assert stats["served"] + stats["failed_quarantined"] \
        + stats["rejected"] == stats["requests"]
    assert stats["redeliveries"] >= 1
    # determinism: the replay's fault timeline is identical
    replay = chaos.run_soak(args)
    assert replay["timeline"] == stats["timeline"]


def test_chaos_without_supervision_strands_requests():
    """The converse proof: same fault plan, recovery machinery off —
    requests demonstrably strand (this is the failure mode the
    supervision layer exists to fix)."""
    chaos, args = _chaos_args(no_supervision=True, max_rounds=250)
    stats = chaos.run_soak(args)
    assert stats["stranded"] > 0
    assert stats["dead_instances"] == []         # controller never learned


# ---------------------------------------------------------------------------
# hang fault + round watchdog
# ---------------------------------------------------------------------------

def test_hung_engine_stalls_without_raising():
    """The hang kind is the no-exception failure mode: rounds 'succeed'
    with zero progress, swap_model is a no-op, dead stays False — only
    the watchdog can see it."""
    from repro.serving.faults import FaultyEngine
    plan = FaultPlan([FaultSpec("round", "hang", at_count=2)], seed=0)
    eng = FaultyEngine(_StubEngine(), plan, engine_id=0)
    eng.step()                         # occurrence 1: fine
    assert not eng.hung
    for _ in range(5):
        assert eng.step() == []        # occurrence 2+: silent stall
    assert eng.hung and not eng.dead
    assert eng.steps(3) == []
    assert eng.swap_model("other", None, None) == []
    # occurrence counters froze at the hang: replay stays deterministic
    assert len(plan.events) == 1 and plan.events[0]["kind"] == "hang"


def test_watchdog_detects_hang_and_kills_without_exception():
    """A busy instance whose progress marker stays flat past the grace
    budget is DEGRADED, then mark_dead exactly like a crash — with no
    exception involved anywhere (crash-only supervision misses this)."""
    a, b = _instance(0, ["m"]), _instance(1, ["m"])
    c = _controller([a, b], hang_grace_rounds=2.0, backoff_base_s=0.1)
    # round deadline from _hw: 0.05 + 0.02*1 + 0.2 = 0.27; budget 0.54
    stub, peer = _StubEngine(), _StubEngine()
    c.attach_engines([stub, peer])
    r = make_request([1, 2, 3], "m", "batch1", arrival_time=0.0,
                     max_new_tokens=4)
    assert c.submit(r, 0.0)
    r._in_flight, r._served_by = True, 0
    stub.resident = [r]

    c.check_watchdog(0.0)                       # baseline marker
    assert c.health[0].state == HEALTHY
    c.check_watchdog(0.4)                       # inside budget: fine
    assert c.health[0].state == HEALTHY
    c.check_watchdog(0.6)                       # past 0.54: degraded
    assert c.health[0].state == DEGRADED
    # progress resets the stall clock AND heals nothing by itself
    stub.stats.tokens_generated += 1
    c.check_watchdog(0.7)
    c.check_watchdog(1.2)                       # only 0.5 stalled again
    assert c.health[0].state == DEGRADED
    c.check_watchdog(0.7 + 0.54 * 3.0 + 0.01)   # past dead factor: killed
    assert c.health[0].state == DEAD and c.hangs == 1
    assert "hang" in c.health[0].cause
    # the stuck resident was redelivered to the survivor, not lost
    assert not r._in_flight and r.redeliveries == 1
    assert any(r in g.requests for g in b.virtual_queue.groups)


def test_watchdog_ignores_idle_instances():
    """No work, no deadline: an idle engine's flat counters are not a
    hang (otherwise every quiet instance would be culled)."""
    c = _controller([_instance(0, ["m"])], hang_grace_rounds=1.0)
    c.attach_engines([_StubEngine()])
    for t in (0.0, 5.0, 50.0):
        c.check_watchdog(t)
    assert c.health[0].state == HEALTHY and c.hangs == 0


# ---------------------------------------------------------------------------
# drain lifecycle
# ---------------------------------------------------------------------------

def test_drain_lets_residents_finish_with_zero_evictions():
    """Graceful decommission: DRAINING stops new placement while the
    resident finishes in place; the empty engine is then DRAINED —
    no eviction, no redelivery, no failure."""
    a, b = _instance(0, ["m"]), _instance(1, ["m"])
    c = _controller([a, b])
    stub, peer = _StubEngine(), _StubEngine()
    c.attach_engines([stub, peer])
    r = make_request([1, 2], "m", "batch1", arrival_time=0.0,
                     max_new_tokens=4)
    assert c.submit(r, 0.0)
    r._in_flight, r._served_by = True, 0
    stub.resident = [r]
    stub.slots = [r]          # invariant checks look at the slot table

    c.drain_instance(0, 1.0)
    assert c.health[0].state == DRAINING and c.drains == 1
    assert c.is_alive(0) and not c.is_schedulable(0)
    assert not a.virtual_queue.groups            # no longer pullable here
    # new work routes around the draining instance
    r2 = make_request([3, 4], "m", "batch1", arrival_time=1.5)
    assert c.submit(r2, 1.5)
    assert any(r2 in g.requests for g in b.virtual_queue.groups)
    # resident still finishing: not decommissioned yet
    c._finish_drains(2.0)
    assert c.health[0].state == DRAINING
    # resident completes in place -> DRAINED, with zero evictions
    r.generated = 4
    r.completion_time = 2.5
    r._in_flight = False
    stub.resident = []
    stub.slots = []
    c._finish_drains(3.0)
    assert c.health[0].state == DRAINED
    assert not c.is_alive(0)
    assert stub.stats.evictions == 0
    assert r.redeliveries == 0 and not r.failed
    assert c.serving_fraction() == 0.5 and c.alive_fraction() == 0.5


def test_drain_only_from_healthy_or_degraded():
    c = _controller([_instance(0, ["m"])])
    c.attach_engines([_StubEngine()])
    c.mark_dead(0, 1.0, cause="gone")
    c.drain_instance(0, 2.0)
    assert c.health[0].state == DEAD and c.drains == 0


# ---------------------------------------------------------------------------
# instance replacement
# ---------------------------------------------------------------------------

def test_replace_instance_serves_redelivered_work():
    """Kill-then-replace end to end on real engines: the replacement
    engine takes the dead slot and the redelivered requests finish."""
    chaos, args = _chaos_args(scenario="kill-replace", requests=12,
                              rate=20.0, max_rounds=800)
    stats = chaos.run_soak(args)
    assert stats["engine_failures"] >= 1
    assert stats["replacements"] >= 1
    assert stats["dead_instances"] == []         # replaced, not a hole
    assert stats["stranded"] == 0
    assert stats["served"] == stats["requests"]
    assert stats["leaked_blocks"] == []


def test_replace_instance_rejects_live_slot():
    c = _controller([_instance(0, ["m"])])
    c.attach_engines([_StubEngine()])
    with pytest.raises(ValueError):
        c.replace_instance(0, _StubEngine(), 1.0)
    c.mark_dead(0, 1.0, cause="gone")
    fresh = _StubEngine()
    c.replace_instance(0, fresh, 2.0)
    assert c.health[0].state == HEALTHY and c.is_schedulable(0)
    assert c.replacements == 1
    assert c._engines[0] is fresh


def test_replacement_policy_signals():
    import math
    from repro.core.autoscale import ReplacementPolicy
    c = _controller([_instance(0, ["m"]), _instance(1, ["m"])])
    c.attach_engines([_StubEngine(), _StubEngine()])
    pol = ReplacementPolicy(cooldown_s=10.0)
    assert pol.replacements_due(c, 0.0) == []    # everyone healthy
    c.mark_dead(1, 1.0, cause="gone")
    assert pol.replacements_due(c, 2.0) == [1]
    assert pol.replacements_due(c, 3.0) == []    # inside the cooldown
    assert pol.replacements_due(c, 13.0) == [1]
    # queue-drain signal: no schedulable capacity + backlog = infinite
    r = make_request([1, 2], "m", "batch1", arrival_time=0.0)
    assert c.submit(r, 0.0)
    c.mark_dead(0, 14.0, cause="gone")           # quarantines r (unservable)
    assert pol.queue_drain_s(c) == 0.0           # nothing queued anymore
    assert c.submit(make_request([1], "m", "batch1", arrival_time=15.0),
                    15.0) is False               # all-dead gate: rejected


# ---------------------------------------------------------------------------
# zero-capacity guards + redelivery deadline overshoot
# ---------------------------------------------------------------------------

def test_all_dead_cluster_rejects_without_exceptions():
    c = _controller([_instance(0, ["m"]), _instance(1, ["m"])])
    c.attach_engines([_StubEngine(), _StubEngine()])
    c.mark_dead(0, 1.0, cause="gone")
    c.mark_dead(1, 1.0, cause="gone")
    assert c.alive_fraction() == 0.0 and c.serving_fraction() == 0.0
    assert not c.can_serve("m")
    r = make_request([1], "m", "interactive", arrival_time=2.0)
    assert c.submit(r, 2.0) is False and r.rejected
    c.tick(3.0)                                  # ticking a dead cluster: ok
    c.check_watchdog(3.0)
    c.gc_groups()


def test_redelivery_backoff_overshooting_deadline_quarantines():
    """A redelivered request whose backoff window lands past its deadline
    can never be served in time: quarantine immediately instead of
    burning a pull + prefill on a guaranteed miss."""
    inst = _instance(0, ["m"])
    c = _controller([inst], retry_budget=5, backoff_base_s=10.0,
                    backoff_cap_s=10.0)
    r = make_request([1, 2], "m", "interactive", arrival_time=0.0)
    r.slo = 1.0                                  # deadline = 1.0
    assert c.submit(r, 0.0)
    c._redeliver(r, 0.5)                         # 0.5 + 10.0 >> 1.0
    assert r.failed and r.dropped()
    assert "overshoots deadline" in r.fail_cause
    assert r in c.failed
    # but a request that already streamed its first token is NOT cut off
    r2 = make_request([1, 2], "m", "interactive", arrival_time=0.0)
    r2.slo = 1.0
    assert c.submit(r2, 0.0)
    r2.first_token_time = 0.2
    c._redeliver(r2, 0.5)
    assert not r2.failed and r2.not_before == pytest.approx(10.5)


# ---------------------------------------------------------------------------
# FaultPlan JSON round trip
# ---------------------------------------------------------------------------

def test_fault_plan_from_json_replays_identically():
    import json as _json
    specs = [FaultSpec("decode", "error", prob=0.2, max_fires=3),
             FaultSpec("round", "hang", engine=0, at_count=5),
             FaultSpec("decode", "crash", engine=1, at_count=7)]
    plan = FaultPlan(specs, seed=11)
    blob = _json.dumps({
        "seed": 11,
        "specs": [{"site": s.site, "kind": s.kind, "engine": s.engine,
                   "at_count": s.at_count, "prob": s.prob,
                   "max_fires": s.max_fires} for s in specs],
        "events": [{"stale": "timeline entries must be dropped"}],
    })
    loaded = FaultPlan.from_json(blob)
    assert loaded.seed == 11 and not loaded.events
    fresh = plan.fresh()
    assert _drive(loaded) == _drive(fresh)
    assert loaded.timeline() == fresh.timeline()
    with pytest.raises(ValueError):
        FaultPlan.from_json(_json.dumps({"seed": 0, "specs": [
            {"site": "decode", "kind": "meltdown", "at_count": 1}]}))


# ---------------------------------------------------------------------------
# cross-engine snapshot migration (real engines)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mig_model():
    import jax
    from repro.configs import ARCHITECTURES
    from repro.models import build_model
    cfg = ARCHITECTURES["granite-3-2b"].reduced(num_layers=1, d_model=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return model, params


def _mig_engine(model, params):
    from repro.serving import ContinuousBatchingEngine, EngineConfig
    cfg = EngineConfig(max_slots=4, max_seq_len=64, block_size=8,
                       prefill_chunk_tokens=16,
                       attention_backend="paged-xla", prefix_sharing=True)
    return ContinuousBatchingEngine(model, params, cfg, model_name="m1")


def test_migrated_snapshot_resumes_token_identical(mig_model):
    """The migration contract end to end at the engine layer: a live-
    pinned mid-decode snapshot is materialized on its source engine,
    resumed on a DIFFERENT engine, and finishes with exactly the tokens
    an uninterrupted run produces — with the source pool fully released
    (source pins dropped iff destination pages live)."""
    from repro.core.request import Request
    model, params = mig_model
    shared = list(range(1, 13))                 # > 1 full block shared
    prompts = [shared + [50, 51], shared + [60, 61, 62]]

    def req(p):
        return Request(prompt_tokens=list(p), model="m1", slo=1e9,
                       max_new_tokens=6)

    # uninterrupted baseline on a single engine
    base = _mig_engine(model, params)
    base_reqs = [req(p) for p in prompts]
    assert base.admit(base_reqs[0])
    while base.prefilling_slots():
        base.step()
    assert base.admit(base_reqs[1])
    for _ in range(80):
        base.step()
        if all(r.finished() for r in base_reqs):
            break
    want = [r.output_tokens for r in base_reqs]
    assert all(len(t) == 6 for t in want)

    # source engine: same admissions, evict rb mid-decode (pins exist
    # because ra still shares the prefix chain)
    eng_a = _mig_engine(model, params)
    eng_b = _mig_engine(model, params)
    ra, rb = [req(p) for p in prompts]
    assert eng_a.admit(ra)
    while eng_a.prefilling_slots():
        eng_a.step()
    assert eng_a.admit(rb)
    eng_a.step()
    eng_a.step()
    assert rb.generated > 0                     # genuinely mid-decode
    eng_a.evict_request(rb.req_id)
    assert rb.snapshot["pinned"], "no pins: the scenario is vacuous"
    # a live-pinned mid-decode snapshot is engine-local...
    assert not eng_b.can_admit(rb)
    # ...until the owner materializes it into portable form
    assert eng_a.materialize_snapshot(rb)
    assert rb.snapshot is not None and not rb.snapshot["pinned"]
    assert eng_a.stats.migrations_out == 1
    # destination resumes it mid-decode, token state intact
    assert eng_b.admit(rb)
    assert eng_b.stats.migrations_in == 1 and eng_b.stats.resumes == 1
    for _ in range(80):
        eng_a.step()
        eng_b.step()
        if ra.finished() and rb.finished():
            break
    assert ra.finished() and rb.finished()
    assert [ra.output_tokens, rb.output_tokens] == want
    # both pools fully released: no pinned-forever source pages
    assert eng_a.block_mgr.used_blocks == 0 and not eng_a.block_mgr._pins
    assert eng_b.block_mgr.used_blocks == 0


def test_migration_sweep_moves_orphaned_pinned_snapshot(mig_model):
    """Controller-level migration: a queued request whose snapshot pins
    pages on instance A but whose group landed on instance B is
    materialized by the sweep (A's pins released, snapshot portable)."""
    model, params = mig_model
    from repro.core.request import Request
    eng_a, eng_b = _mig_engine(model, params), _mig_engine(model, params)
    a, b = _instance(0, ["m1"]), _instance(1, ["m1"])
    c = _controller([a, b])
    c.attach_engines([eng_a, eng_b])

    shared = list(range(1, 13))
    ra = Request(prompt_tokens=shared + [50], model="m1", slo=1e9,
                 max_new_tokens=6, arrival_time=0.0)
    rb = Request(prompt_tokens=shared + [60, 61], model="m1", slo=1e9,
                 max_new_tokens=6, arrival_time=0.0)
    assert c.submit(ra, 0.0) and c.submit(rb, 0.0)
    assert eng_a.admit(ra)
    ra._in_flight, ra._served_by = True, 0
    while eng_a.prefilling_slots():
        eng_a.step()
    assert eng_a.admit(rb)
    eng_a.step()
    eng_a.step()
    eng_a.evict_request(rb.req_id)
    assert rb.snapshot["pinned"]
    # strand rb's placement on instance 1 while its pins live in pool 0
    rb._in_flight, rb._served_by = False, None
    for g in list(a.virtual_queue.groups):
        if rb in g.requests:
            a.virtual_queue.groups.remove(g)
            b.virtual_queue.groups.append(g)
    migrated_before = c.migrations
    c.migration_sweep(1.0)
    assert c.migrations == migrated_before + 1
    assert rb.snapshot is not None and not rb.snapshot["pinned"]
    # destination can now take it; source keeps serving ra
    assert eng_b.admit(rb)
    for _ in range(80):
        eng_a.step()
        eng_b.step()
        if ra.finished() and rb.finished():
            break
    assert ra.finished() and rb.finished()
    assert eng_a.block_mgr.used_blocks == 0 and not eng_a.block_mgr._pins
