"""Fault-tolerance tests: seeded fault injection (serving/faults), the
controller supervision layer (health states, heartbeats, mark_dead),
request redelivery with backoff + poison quarantine, pinned-snapshot
discard on owner death, and the end-to-end chaos soak (launch/chaos).

The pyramid: unit tests drive the supervision machinery against stub
instances/engines (fast, exact); the two soak tests at the bottom run
the real JAX engines under a seeded kill and assert the recovery
contract — plus its converse: with supervision off the same plan
demonstrably strands requests.
"""
import argparse

import pytest

from repro.analysis.invariants import (InvariantViolation,
                                       check_block_manager,
                                       check_terminal_states)
from repro.core.global_scheduler import InstanceInfo
from repro.core.lso import QLMAgent
from repro.core.qlm import (DEAD, DEGRADED, HEALTHY, QLMConfig,
                            QLMController)
from repro.core.request import make_request
from repro.core.rwt_estimator import HardwareProfile
from repro.core.virtual_queue import VirtualQueue
from repro.serving.faults import (EngineCrashed, EngineDead, FaultPlan,
                                  FaultSpec, TransientEngineError)
from repro.serving.kv_cache import BlockManager


def _hw(**kw):
    base = dict(prefill_time=0.05, decode_per_token=0.02, inefficiency=1.2,
                token_capacity=512, swap_time=0.2, model_max_tokens=32)
    base.update(kw)
    return HardwareProfile(**base)


def _instance(iid, models, current=None):
    return InstanceInfo(iid, {m: _hw() for m in models}, current,
                        VirtualQueue(iid))


def _controller(instances, **cfg):
    cfg.setdefault("avg_batch_size", 4)
    cfg.setdefault("reschedule_on_arrival", False)
    return QLMController(instances, QLMConfig(**cfg))


class _StubEngine:
    """Just enough engine surface for mark_dead / QLMAgent plumbing."""

    def __init__(self, resident=(), block_mgr=None):
        self.resident = list(resident)
        self.block_mgr = block_mgr
        self.slots = []
        self._pushback = None
        self.pull_source = None

    def abandon(self):
        out, self.resident = self.resident, []
        for r in out:
            r._in_flight = False
        return out

    def take_pushback(self):
        p, self._pushback = self._pushback, None
        return p

    def _materialize_pinned_snapshots(self):
        pass


# ---------------------------------------------------------------------------
# FaultPlan: seeded determinism
# ---------------------------------------------------------------------------

def _drive(plan, rounds=40):
    fired = []
    for _ in range(rounds):
        for eng in (0, 1):
            for site in ("round", "decode"):
                spec = plan.fire(eng, site)
                if spec is not None:
                    fired.append((eng, site, spec.kind))
    return fired


def test_fault_plan_replays_identically_from_seed():
    specs = [FaultSpec("decode", "error", prob=0.15, max_fires=3),
             FaultSpec("round", "crash", engine=1, at_count=7),
             FaultSpec("decode", "error", prob=0.3, max_fires=2)]
    plan = FaultPlan(specs, seed=42)
    first = _drive(plan)
    assert first, "plan never fired — test is vacuous"
    assert (1, "round", "crash") in first
    # same seed, fresh state -> identical firing sequence AND timeline
    replay = plan.fresh()
    assert _drive(replay) == first
    assert replay.timeline() == plan.timeline()
    # a different seed diverges somewhere (probabilistic specs redraw)
    other = FaultPlan(specs, seed=43)
    assert _drive(other) != first


def test_fault_plan_per_spec_rng_isolation():
    """Removing one probabilistic spec must not shift another spec's
    draw sequence (per-spec RNGs, not one shared stream)."""
    a = FaultSpec("decode", "error", prob=0.2, max_fires=100)
    b = FaultSpec("round", "error", prob=0.2, max_fires=100)
    both = FaultPlan([a, b], seed=7)
    only_b_events = [e for e in (_drive(both), both.events)[1]
                     if e["spec"] == 1]
    solo = FaultPlan([b], seed=7)
    # spec b sits at a different index in the solo plan, so reseed it the
    # way the plan does: index 1 in `both`
    solo._rngs[0] = type(solo._rngs[0])((7 << 8) ^ 1)
    _drive(solo)
    assert [(e["engine"], e["occurrence"]) for e in solo.events] \
        == [(e["engine"], e["occurrence"]) for e in only_b_events]


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec("nope", "crash", at_count=1)
    with pytest.raises(ValueError):
        FaultSpec("decode", "meltdown", at_count=1)
    with pytest.raises(ValueError):
        FaultSpec("decode", "crash")          # neither at_count nor prob


def test_crashed_engine_stays_dead():
    plan = FaultPlan([FaultSpec("round", "crash", at_count=1)], seed=0)
    from repro.serving.faults import FaultyEngine
    eng = FaultyEngine(_StubEngine(), plan, engine_id=0)
    with pytest.raises(EngineCrashed):
        eng.step()
    assert eng.dead
    with pytest.raises(EngineDead):
        eng.step()
    assert eng.cancel_request(object()) is False


# ---------------------------------------------------------------------------
# backoff math
# ---------------------------------------------------------------------------

def test_backoff_monotone_and_capped():
    c = _controller([_instance(0, ["m"])],
                    backoff_base_s=0.1, backoff_cap_s=1.0)
    seq = [c.backoff(n) for n in range(1, 10)]
    assert seq[0] == pytest.approx(0.1)
    assert seq[1] == pytest.approx(0.2)
    assert all(b2 >= b1 for b1, b2 in zip(seq, seq[1:]))
    assert seq[-1] == 1.0 and max(seq) == 1.0


def test_backoff_gates_fcfs_pull():
    """A redelivered request is invisible to pulls until not_before."""
    inst = _instance(0, ["m"])
    c = _controller([inst])
    r = make_request([1, 2], "m", "batch1", arrival_time=0.0)
    assert c.submit(r, 0.0)
    r.not_before = 5.0
    assert inst.virtual_queue.next_request("m", now=1.0) is None
    assert inst.virtual_queue.next_request("m", now=5.0) is r


# ---------------------------------------------------------------------------
# health state machine
# ---------------------------------------------------------------------------

def test_transient_strikes_then_death_and_heartbeat_recovery():
    c = _controller([_instance(0, ["m"])], transient_strikes=3)
    e = TransientEngineError("flaky")
    assert c.report_engine_failure(0, e, 1.0) == DEGRADED
    assert c.health[0].strikes == 1
    # a good iteration heals the strike counter and the state
    c.heartbeat(0, 2.0)
    assert c.health[0].state == HEALTHY and c.health[0].strikes == 0
    # three consecutive strikes without a heartbeat give up on it
    assert c.report_engine_failure(0, e, 3.0) == DEGRADED
    assert c.report_engine_failure(0, e, 4.0) == DEGRADED
    assert c.report_engine_failure(0, e, 5.0) == DEAD
    assert not c.is_alive(0)
    # dead is terminal: neither heartbeats nor further reports revive it
    c.heartbeat(0, 6.0)
    assert c.report_engine_failure(0, e, 7.0) == DEAD


def test_fatal_exception_kills_immediately():
    c = _controller([_instance(0, ["m"]), _instance(1, ["m"])])
    assert c.report_engine_failure(1, EngineCrashed("boom"), 1.0) == DEAD
    assert c.health[1].cause and "boom" in c.health[1].cause
    assert [c.is_alive(0), c.is_alive(1)] == [True, False]
    assert len(c.alive_instances()) == 1 and c.alive_fraction() == 0.5


def test_heartbeat_timeout_degrades_then_kills():
    c = _controller([_instance(0, ["m"])], heartbeat_timeout_s=1.0,
                    degraded_after_missed=1, dead_after_missed=3)
    c.check_heartbeats(10.0)          # first sight: starts the window
    assert c.health[0].state == HEALTHY
    c.check_heartbeats(11.5)          # 1 missed window
    assert c.health[0].state == DEGRADED
    c.heartbeat(0, 11.6)              # sign of life: recover
    assert c.health[0].state == HEALTHY
    c.check_heartbeats(14.7)          # 3 windows since 11.6
    assert c.health[0].state == DEAD
    assert "heartbeat" in c.health[0].cause


# ---------------------------------------------------------------------------
# mark_dead: redelivery, exclusion, quarantine
# ---------------------------------------------------------------------------

def test_mark_dead_redelivers_resident_requests():
    a, b = _instance(0, ["m"]), _instance(1, ["m"])
    c = _controller([a, b], retry_budget=2, backoff_base_s=0.5)
    t0 = 1.0
    r = make_request([1, 2, 3], "m", "batch1", arrival_time=t0)
    assert c.submit(r, t0)
    # simulate instance 1 having pulled it
    r._in_flight = True
    r._served_by = 1
    eng = _StubEngine(resident=[r])
    c.mark_dead(1, 5.0, cause="test-kill", engine=eng)

    assert not c.is_alive(1)
    assert not b.virtual_queue.groups            # dead VQ emptied
    assert r in c.global_queue and not r.finished()
    assert not r._in_flight and r._served_by is None
    assert r.redeliveries == 1 and c.redeliveries == 1
    assert r.not_before == pytest.approx(5.0 + 0.5)
    # the group is reachable again from the survivor
    assert any(r in g.requests for g in a.virtual_queue.groups)
    # and the survivor can actually hand it out once backoff expires
    assert a.virtual_queue.next_request("m", now=6.0) is r


def test_retry_budget_exhaustion_quarantines_as_miss():
    inst = _instance(0, ["m"])
    c = _controller([inst], retry_budget=2)
    t0 = 0.0
    r = make_request([1, 2], "m", "interactive", arrival_time=t0)
    assert c.submit(r, t0)
    for n in (1, 2):
        c._redeliver(r, float(n))
        assert r.redeliveries == n and not r.failed
    c._redeliver(r, 3.0)                         # third death: poison
    assert r.failed and r.dropped() and r.finished()
    assert "retry budget" in r.fail_cause
    assert r in c.failed and r.completion_time == 3.0
    c.gc_groups()
    assert r in c.finished
    # an unconditional miss, even with a pre-crash first token in time
    r.first_token_time = t0 + 0.1
    assert c.slo_attainment(4.0) < 1.0


def test_mark_dead_quarantines_unservable_models():
    a, b = _instance(0, ["m1"]), _instance(1, ["m2"])
    c = _controller([a, b])
    r = make_request([1, 2], "m2", "batch1", arrival_time=0.0)
    assert c.submit(r, 0.0)
    c.mark_dead(1, 1.0, cause="only m2 server dies")
    assert r.failed and "unservable" in r.fail_cause
    assert r in c.failed
    # the controller now refuses new m2 work at the gate
    r2 = make_request([3], "m2", "batch1", arrival_time=2.0)
    assert c.submit(r2, 2.0) is False and r2.rejected


def test_mark_dead_discards_snapshots_pinned_in_dead_pool():
    """A request evicted WITH pinned prefix blocks in the dead engine's
    pool: the pins are released (dead pool conserves) and the request
    restarts cleanly on a survivor — generated tokens wiped, attempt
    accounting intact."""
    bm = BlockManager(16, 4, cache_freed=True)
    bm.attach_slot_table(4, 16)
    bm.allocate(1, 8)
    bm.bind_slot(1, 0)
    bm.register_prefix(1, list(range(8)), 8)
    bm.fork(1, 2)                     # prefix now shared -> evictable pins
    bm.bind_slot(2, 1)
    pinned, _private = bm.evict_split(1)
    assert pinned and bm._pins
    check_block_manager(bm)

    a, b = _instance(0, ["m"]), _instance(1, ["m"])
    c = _controller([a, b])
    t0 = 0.0
    r = make_request(list(range(8)), "m", "batch1", arrival_time=t0)
    assert c.submit(r, t0)
    r.generated = 3
    r.output_tokens.extend([7, 8, 9])
    r.first_token_time = t0 + 0.2
    r.snapshot = {"pinned": pinned, "pin_owner": bm, "pin_epoch": bm.epoch}

    c.mark_dead(1, 1.0, cause="pool dies", engine=_StubEngine(block_mgr=bm))
    assert not bm._pins, "pins must die with the owner"
    bm.free(2)
    assert not bm._seqs
    check_block_manager(bm)
    # clean restart: no half-generated state, no stale snapshot
    assert r.snapshot is None and r.generated == 0 and r.output_tokens == []
    assert r.first_token_time == t0 + 0.2        # kept: no double-count
    assert not r.finished() and r in c.global_queue


def test_agent_reset_clears_head_and_pushback():
    eng = _StubEngine()
    agent = QLMAgent(eng, VirtualQueue(0), {})
    agent._last_head = object()
    limbo = make_request([1], "m", "batch1")
    limbo._in_flight = True
    limbo._served_by = 0
    eng._pushback = limbo
    agent.reset()
    assert agent._last_head is None
    assert eng._pushback is None
    assert not limbo._in_flight and limbo._served_by is None


# ---------------------------------------------------------------------------
# terminal-state conservation
# ---------------------------------------------------------------------------

def test_terminal_states_clean_pass_and_stranded_caught():
    inst = _instance(0, ["m"])
    c = _controller([inst])
    r = make_request([1, 2], "m", "batch1", arrival_time=0.0)
    assert c.submit(r, 0.0)
    check_terminal_states(c)                     # queued + placed: fine

    # in-flight but resident in no alive engine == stranded
    r._in_flight = True
    with pytest.raises(InvariantViolation) as e:
        check_terminal_states(c, engines=[_StubEngine()])
    assert "in-flight" in str(e.value) or "resident" in str(e.value)

    # a failed request must carry a completion stamp (liveness leak)
    r._in_flight = False
    r.failed = True
    c.failed.append(r)
    with pytest.raises(InvariantViolation):
        check_terminal_states(c)
    r.completion_time = 1.0
    check_terminal_states(c)


# ---------------------------------------------------------------------------
# end-to-end: seeded chaos soak on real engines
# ---------------------------------------------------------------------------

def _chaos_args(**over):
    from repro.launch import chaos
    ap_defaults = dict(arch="granite-3-2b", instances=2, requests=10,
                       rate=8.0, max_new_tokens=8, slots=4, seed=0,
                       site="decode", kill_engine=1, kill_at=2,
                       error_prob=0.0, retry_budget=2, round_dt=0.05,
                       max_rounds=600, attainment_floor=0.5,
                       no_supervision=False, replay_check=False,
                       json=None, timeline=None)
    ap_defaults.update(over)
    return chaos, argparse.Namespace(**ap_defaults)


def test_chaos_soak_recovers_from_engine_death():
    chaos, args = _chaos_args()
    stats = chaos.run_soak(args)
    assert stats["dead_instances"] == [1]
    assert stats["stranded"] == 0
    assert stats["leaked_blocks"] == []
    assert stats["served"] + stats["failed_quarantined"] \
        + stats["rejected"] == stats["requests"]
    assert stats["redeliveries"] >= 1
    # determinism: the replay's fault timeline is identical
    replay = chaos.run_soak(args)
    assert replay["timeline"] == stats["timeline"]


def test_chaos_without_supervision_strands_requests():
    """The converse proof: same fault plan, recovery machinery off —
    requests demonstrably strand (this is the failure mode the
    supervision layer exists to fix)."""
    chaos, args = _chaos_args(no_supervision=True, max_rounds=250)
    stats = chaos.run_soak(args)
    assert stats["stranded"] > 0
    assert stats["dead_instances"] == []         # controller never learned
