"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes/dtypes
(interpret=True executes the Pallas kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KVH,Lq,Lkv,D,bq,bk", [
    (1, 4, 4, 64, 64, 32, 32, 32),     # MHA square
    (2, 8, 2, 100, 100, 64, 32, 32),   # GQA, non-multiple lengths (padding)
    (1, 4, 1, 33, 65, 16, 16, 16),     # MQA, ragged
])
def test_flash_attention_causal(B, H, KVH, Lq, Lkv, D, bq, bk, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, H, Lq, D), dtype)
    k = jax.random.normal(ks[1], (B, KVH, Lkv, D), dtype)
    v = jax.random.normal(ks[2], (B, KVH, Lkv, D), dtype)
    out = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [8, 17, 64])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.key(1), 3)
    B, H, KVH, L, D = 2, 4, 2, 80, 32
    q = jax.random.normal(ks[0], (B, H, L, D))
    k = jax.random.normal(ks[1], (B, KVH, L, D))
    v = jax.random.normal(ks[2], (B, KVH, L, D))
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=16, block_k=16)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KVH,S,D,bk", [
    (2, 8, 2, 300, 64, 64),
    (1, 4, 4, 17, 32, 8),
    (3, 6, 1, 128, 16, 32),
])
def test_decode_attention(B, H, KVH, S, D, bk, dtype):
    ks = jax.random.split(jax.random.key(2), 4)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, KVH, S, D), dtype)
    v = jax.random.normal(ks[2], (B, KVH, S, D), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, S + 1, jnp.int32)
    out = ops.decode_attention(q, k, v, lengths, block_k=bk)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_decode_attention_respects_lengths():
    """Tokens beyond `lengths` must not influence the output."""
    ks = jax.random.split(jax.random.key(3), 3)
    B, H, KVH, S, D = 1, 2, 2, 64, 16
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, KVH, S, D))
    v = jax.random.normal(ks[2], (B, KVH, S, D))
    lengths = jnp.array([20], jnp.int32)
    out1 = ops.decode_attention(q, k, v, lengths)
    k2 = k.at[:, :, 20:].set(999.0)
    v2 = v.at[:, :, 20:].set(-999.0)
    out2 = ops.decode_attention(q, k2, v2, lengths)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("B,L,H,P,G,N,chunk", [
    (1, 64, 2, 16, 1, 8, 16),
    (2, 128, 4, 32, 2, 16, 32),
    (1, 32, 8, 8, 4, 4, 8),
])
def test_ssd_scan_kernel(B, L, H, P, G, N, chunk, dtype):
    ks = jax.random.split(jax.random.key(4), 5)
    x = jax.random.normal(ks[0], (B, L, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, L, G, N), dtype)
    Cm = jax.random.normal(ks[4], (B, L, G, N), dtype)
    out = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    want = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=5e-4, atol=5e-4)
