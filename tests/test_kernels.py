"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes/dtypes
(interpret=True executes the Pallas kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.pallas


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KVH,Lq,Lkv,D,bq,bk", [
    (1, 4, 4, 64, 64, 32, 32, 32),     # MHA square
    (2, 8, 2, 100, 100, 64, 32, 32),   # GQA, non-multiple lengths (padding)
    (1, 4, 1, 33, 65, 16, 16, 16),     # MQA, ragged
])
def test_flash_attention_causal(B, H, KVH, Lq, Lkv, D, bq, bk, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, H, Lq, D), dtype)
    k = jax.random.normal(ks[1], (B, KVH, Lkv, D), dtype)
    v = jax.random.normal(ks[2], (B, KVH, Lkv, D), dtype)
    out = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [8, 17, 64])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.key(1), 3)
    B, H, KVH, L, D = 2, 4, 2, 80, 32
    q = jax.random.normal(ks[0], (B, H, L, D))
    k = jax.random.normal(ks[1], (B, KVH, L, D))
    v = jax.random.normal(ks[2], (B, KVH, L, D))
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=16, block_k=16)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KVH,S,D,bk", [
    (2, 8, 2, 300, 64, 64),
    (1, 4, 4, 17, 32, 8),
    (3, 6, 1, 128, 16, 32),
])
def test_decode_attention(B, H, KVH, S, D, bk, dtype):
    ks = jax.random.split(jax.random.key(2), 4)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, KVH, S, D), dtype)
    v = jax.random.normal(ks[2], (B, KVH, S, D), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, S + 1, jnp.int32)
    out = ops.decode_attention(q, k, v, lengths, block_k=bk)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_decode_attention_respects_lengths():
    """Tokens beyond `lengths` must not influence the output."""
    ks = jax.random.split(jax.random.key(3), 3)
    B, H, KVH, S, D = 1, 2, 2, 64, 16
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, KVH, S, D))
    v = jax.random.normal(ks[2], (B, KVH, S, D))
    lengths = jnp.array([20], jnp.int32)
    out1 = ops.decode_attention(q, k, v, lengths)
    k2 = k.at[:, :, 20:].set(999.0)
    v2 = v.at[:, :, 20:].set(-999.0)
    out2 = ops.decode_attention(q, k2, v2, lengths)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


def _paged_from_dense(k_dense, v_dense, block_size, num_pool_blocks, rng):
    """Scatter dense (B, KVH, S, D) k/v into a page pool through a random
    (non-contiguous) page assignment; returns (k_pages, v_pages, block_table)."""
    B, KVH, S, D = k_dense.shape
    nb = S // block_size
    assert nb * block_size == S
    perm = rng.permutation(num_pool_blocks)[:B * nb].reshape(B, nb)
    k_pages = rng.standard_normal((num_pool_blocks, KVH, block_size, D)) \
        .astype(k_dense.dtype)  # unowned pages hold garbage on purpose
    v_pages = rng.standard_normal((num_pool_blocks, KVH, block_size, D)) \
        .astype(v_dense.dtype)
    for b in range(B):
        for i in range(nb):
            k_pages[perm[b, i]] = k_dense[b, :, i * block_size:(i + 1) * block_size]
            v_pages[perm[b, i]] = v_dense[b, :, i * block_size:(i + 1) * block_size]
    return k_pages, v_pages, perm.astype(np.int32)


@pytest.mark.parametrize("B,H,KVH,S,D,bs", [
    (2, 8, 2, 64, 32, 16),
    (3, 4, 4, 40, 16, 8),
    (1, 6, 1, 24, 64, 4),
])
def test_paged_decode_attention(B, H, KVH, S, D, bs):
    """Block-table kernel == dense oracle through a permuted page pool."""
    rng = np.random.default_rng(10)
    q = rng.standard_normal((B, H, D)).astype(np.float32)
    k = rng.standard_normal((B, KVH, S, D)).astype(np.float32)
    v = rng.standard_normal((B, KVH, S, D)).astype(np.float32)
    kp, vp, bt = _paged_from_dense(k, v, bs, 4 * B * (S // bs), rng)
    lengths = rng.integers(1, S + 1, size=B).astype(np.int32)
    out = ops.paged_decode_attention(q, kp, vp, bt, lengths)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-5, atol=2e-5)
    # and the XLA gather reference agrees with both
    want2 = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(want2, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_attention_sentinel_blocks_ignored():
    """Logical blocks past `lengths` may hold sentinel (out-of-pool) page
    ids — required by the engine, whose tables are sentinel-padded."""
    rng = np.random.default_rng(11)
    B, H, KVH, S, D, bs = 2, 4, 2, 32, 16, 8
    q = rng.standard_normal((B, H, D)).astype(np.float32)
    k = rng.standard_normal((B, KVH, S, D)).astype(np.float32)
    v = rng.standard_normal((B, KVH, S, D)).astype(np.float32)
    kp, vp, bt = _paged_from_dense(k, v, bs, 16, rng)
    lengths = np.array([7, 9], np.int32)   # needs 1 / 2 pages only
    out1 = ops.paged_decode_attention(q, kp, vp, bt, lengths)
    bt_sent = bt.copy()
    bt_sent[0, 1:] = 16   # sentinel = pool size
    bt_sent[1, 2:] = 16
    out2 = ops.paged_decode_attention(q, kp, vp, bt_sent, lengths)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_attention_quant():
    """int8 page pool with per-row scale pages == dequantized oracle."""
    rng = np.random.default_rng(12)
    B, H, KVH, S, D, bs = 2, 8, 2, 48, 32, 8
    nb, N = S // bs, 24
    q = rng.standard_normal((B, H, D)).astype(np.float32)
    kq = rng.integers(-127, 128, size=(N, KVH, bs, D)).astype(np.int8)
    vq = rng.integers(-127, 128, size=(N, KVH, bs, D)).astype(np.int8)
    ks = (rng.random((N, KVH, bs)) * 0.1).astype(np.float32)
    vs = (rng.random((N, KVH, bs)) * 0.1).astype(np.float32)
    bt = rng.permutation(N)[:B * nb].reshape(B, nb).astype(np.int32)
    lengths = np.array([S, 13], np.int32)
    out = ops.paged_decode_attention_quant(q, kq, vq, ks, vs, bt, lengths)
    from repro.kernels.paged_decode_attention import gather_kv_pages
    k = np.asarray(gather_kv_pages(jnp.asarray(kq), jnp.asarray(bt)), np.float32) \
        * np.asarray(gather_kv_pages(jnp.asarray(ks), jnp.asarray(bt)))[..., None]
    v = np.asarray(gather_kv_pages(jnp.asarray(vq), jnp.asarray(bt)), np.float32) \
        * np.asarray(gather_kv_pages(jnp.asarray(vs), jnp.asarray(bt)))[..., None]
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("length", [1, 20, 64])  # incl. the full-cache boundary
def test_decode_attention_quant_length_convention(length):
    """The quant and float decode kernels must consume the SAME (inclusive)
    `lengths` convention: identical int8 content run through the fused
    kernel and through dequantize->float kernel must agree for every
    length, including lengths == S where an off-by-one would read (or drop)
    the final slot."""
    rng = np.random.default_rng(13)
    B, H, KVH, S, D = 2, 4, 2, 64, 16
    q = rng.standard_normal((B, H, D)).astype(np.float32)
    kq = rng.integers(-127, 128, size=(B, KVH, S, D)).astype(np.int8)
    vq = rng.integers(-127, 128, size=(B, KVH, S, D)).astype(np.int8)
    ks = (rng.random((B, KVH, S)) * 0.1).astype(np.float32)
    vs = (rng.random((B, KVH, S)) * 0.1).astype(np.float32)
    lengths = np.array([length, max(1, length - 1)], np.int32)
    from repro.kernels.decode_attention import decode_attention_quant
    out_q = decode_attention_quant(jnp.asarray(q), jnp.asarray(kq),
                                   jnp.asarray(vq), jnp.asarray(ks),
                                   jnp.asarray(vs), jnp.asarray(lengths),
                                   interpret=True)
    k = kq.astype(np.float32) * ks[..., None]
    v = vq.astype(np.float32) * vs[..., None]
    out_f = ops.decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out_q), np.asarray(out_f),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("B,L,H,P,G,N,chunk", [
    (1, 64, 2, 16, 1, 8, 16),
    (2, 128, 4, 32, 2, 16, 32),
    (1, 32, 8, 8, 4, 4, 8),
])
def test_ssd_scan_kernel(B, L, H, P, G, N, chunk, dtype):
    ks = jax.random.split(jax.random.key(4), 5)
    x = jax.random.normal(ks[0], (B, L, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, L, G, N), dtype)
    Cm = jax.random.normal(ks[4], (B, L, G, N), dtype)
    out = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    want = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=5e-4, atol=5e-4)
