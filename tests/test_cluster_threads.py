"""True-concurrency cluster serving (thread-per-engine agents).

The pyramid: unit tests drive the tri-state engine guard, the deferred
salvage/evict machinery, and the slice-level routing policy against stub
engines (fast, exact); the stress test at the bottom runs three REAL
heterogeneous JAX engines on their own threads with submit/cancel/kill/
migrate churn under ``QLINT_INVARIANTS=1`` and asserts the run ends with
zero invariant violations and zero leaked KV blocks.
"""
import argparse
import threading
import time

import pytest

from repro.analysis.invariants import (check_block_manager,
                                       check_migration, check_queue_layer,
                                       check_terminal_states)
from repro.core import routing
from repro.core.global_scheduler import InstanceInfo
from repro.core.qlm import (DEAD, DRAINED, DRAINING, QLMConfig,
                            QLMController, _engine_guard)
from repro.core.request import make_request
from repro.core.request_group import RequestGroup
from repro.core.rwt_estimator import HardwareProfile
from repro.core.solver import GroupSpec, InstanceSpec, per_instance_makespan
from repro.core.virtual_queue import VirtualQueue


def _hw(**kw):
    base = dict(prefill_time=0.05, decode_per_token=0.02, inefficiency=1.2,
                token_capacity=512, swap_time=0.2, model_max_tokens=32)
    base.update(kw)
    return HardwareProfile(**base)


def _instance(iid, models, current=None, **hw_kw):
    return InstanceInfo(iid, {m: _hw(**hw_kw) for m in models}, current,
                        VirtualQueue(iid))


def _controller(instances, **cfg):
    cfg.setdefault("avg_batch_size", 4)
    cfg.setdefault("reschedule_on_arrival", False)
    return QLMController(instances, QLMConfig(**cfg))


class _StubStats:
    tokens_generated = 0
    prefills = 0
    prefill_chunks = 0
    evictions = 0
    resumes = 0
    model_swaps = 0
    cancellations = 0


class _LockedStubEngine:
    """Stub engine WITH a round lock — the threaded-engine shape the
    tri-state guard and the deferral machinery exist for."""

    def __init__(self, resident=()):
        self.lock = threading.RLock()
        self.resident = list(resident)
        self.block_mgr = None
        self.slots = []
        self.stats = _StubStats()
        self.model_name = "m"

    def num_active(self):
        return len(self.resident)

    def abandon(self):
        out, self.resident = self.resident, []
        for r in out:
            r._in_flight = False
        return out

    def take_pushback(self):
        return None


def _hold_lock(lock):
    """Acquire ``lock`` from a helper thread; returns (started, release,
    thread) — the caller release()s to let the thread drop the lock."""
    grabbed, release = threading.Event(), threading.Event()

    def body():
        with lock:
            grabbed.set()
            release.wait(10.0)

    t = threading.Thread(target=body, daemon=True)
    t.start()
    assert grabbed.wait(5.0)
    return release, t


# ---------------------------------------------------------------------------
# tri-state engine guard
# ---------------------------------------------------------------------------

def test_engine_guard_tristate():
    class Lockless:
        pass

    with _engine_guard(Lockless(), timeout=0.1) as got:
        assert got is None          # no lock: proceed unguarded
    with _engine_guard(None, timeout=0.1) as got:
        assert got is None

    eng = _LockedStubEngine()
    with _engine_guard(eng, timeout=0.1) as got:
        assert got is True          # free lock: taken

    release, t = _hold_lock(eng.lock)
    try:
        with _engine_guard(eng, timeout=0.05) as got:
            assert got is False     # contended miss: caller must defer
    finally:
        release.set()
        t.join(5.0)
    # and the guard must not have leaked the (never-acquired) lock
    with _engine_guard(eng, timeout=0.1) as got:
        assert got is True


# ---------------------------------------------------------------------------
# deferred salvage / evict (contended-engine LSOs retried from tick)
# ---------------------------------------------------------------------------

def _dead_engine_setup():
    insts = [_instance(0, ["m"]), _instance(1, ["m"])]
    c = _controller(insts)
    engines = [_LockedStubEngine(), _LockedStubEngine()]
    c.attach_engines(engines)
    r = make_request(list(range(8)), "m", "batch1", arrival_time=0.0,
                     max_new_tokens=4)
    assert c.submit(r, 0.0)
    r._in_flight = True
    r._served_by = 0
    engines[0].resident.append(r)
    return c, engines, r


def test_mark_dead_defers_salvage_while_engine_mid_round():
    c, engines, r = _dead_engine_setup()
    release, t = _hold_lock(engines[0].lock)   # agent "mid-round"
    try:
        c.mark_dead(0, 1.0, cause="test kill")
        # instance is DEAD and its VQ cleared immediately...
        assert c.health[0].state == DEAD
        assert c.instances[0].virtual_queue.groups == []
        # ...but the engine was NOT touched: salvage deferred
        assert c._pending_salvage == [(0, engines[0])]
        assert engines[0].resident == [r]
        assert r._in_flight
    finally:
        release.set()
        t.join(5.0)
    # next tick retries with the lock free: salvage lands
    c.tick(1.1)
    assert c._pending_salvage == []
    assert engines[0].resident == []
    assert not r._in_flight
    assert r.redeliveries == 1
    check_queue_layer(c)


def test_mark_dead_salvages_inline_when_engine_free():
    c, engines, r = _dead_engine_setup()
    c.mark_dead(0, 1.0, cause="test kill")
    assert c._pending_salvage == []
    assert not r._in_flight and r.redeliveries == 1


def test_drain_evict_defers_while_engine_mid_round():
    insts = [_instance(0, ["m"]), _instance(1, ["m"])]
    c = _controller(insts)
    engines = [_LockedStubEngine(), _LockedStubEngine()]
    c.attach_engines(engines)
    release, t = _hold_lock(engines[0].lock)
    try:
        c.drain_instance(0, 1.0, evict=True)
        assert c.health[0].state == DRAINING
        assert 0 in c._pending_evicts
    finally:
        release.set()
        t.join(5.0)
    c.tick(1.1)
    assert c._pending_evicts == {}
    # nothing resident on the stub: the drain completes
    assert c.health[0].state == DRAINED


def test_replace_flushes_deferred_salvage_for_slot():
    c, engines, r = _dead_engine_setup()
    release, t = _hold_lock(engines[0].lock)
    try:
        c.mark_dead(0, 1.0, cause="test kill")
        assert c._pending_salvage
    finally:
        release.set()
        t.join(5.0)
    fresh = _LockedStubEngine()
    c.replace_instance(0, fresh, 2.0)
    # the old engine's salvage ran before the slot was reused
    assert c._pending_salvage == []
    assert engines[0].resident == []
    assert not r._in_flight


# ---------------------------------------------------------------------------
# slice-level routing
# ---------------------------------------------------------------------------

def _reqs(n, model="m", slo_class="batch1"):
    return [make_request(list(range(8)), model, slo_class,
                         arrival_time=float(i) * 0.01, max_new_tokens=4)
            for i in range(n)]


def test_slice_groups_splits_fcfs_and_keeps_small_group_identity():
    small = RequestGroup(model="m", slo=99.0)
    for r in _reqs(3):
        small.add(r)
    big = RequestGroup(model="m", slo=99.0)
    big_members = _reqs(10)
    for r in big_members:
        big.add(r)

    out = routing.slice_groups([small, big], slice_size=4)
    assert any(g is small for g in out)      # identity kept: no id churn
    slices = [g for g in out if g is not small]
    assert [g.size() for g in slices] == [4, 4, 2]
    # FCFS-contiguous: concatenating the slices reproduces the queue
    assert [r for g in slices for r in g.requests] == big_members
    assert all(g.model == "m" for g in slices)
    # members re-tagged to their slice's group id
    for g in slices:
        assert all(r.group_id == g.group_id for r in g.requests)


def test_slice_schedule_places_every_slice_once():
    insts = [_instance(0, ["m"], current="m"),
             _instance(1, ["m"], current="m",
                       prefill_time=0.065, decode_per_token=0.026)]
    c = _controller(insts, routing="slice", slice_size=2)
    for r in _reqs(8):
        assert c.submit(r, 0.0)
    c.reschedule(0.0)
    assert c.routing_invocations >= 1
    live = [g for g in c.groups if not g.done()]
    assert live and all(g.size() <= 2 for g in live)
    placed = [g for inst in c.instances for g in inst.virtual_queue.groups]
    assert sorted(g.group_id for g in placed) \
        == sorted(g.group_id for g in live)      # each exactly once
    # ≥4 slices over a mildly heterogeneous pair: both instances used
    assert all(inst.virtual_queue.groups for inst in c.instances)
    check_queue_layer(c)


def test_routing_policy_validated():
    with pytest.raises(ValueError):
        _controller([_instance(0, ["m"])], routing="bogus")


def test_per_instance_makespan_counts_swaps_on_model_change():
    groups = [GroupSpec(0, "a", 10.0, {0: 1.0, 1: 2.0}),
              GroupSpec(1, "b", 10.0, {0: 1.0, 1: 2.0}),
              GroupSpec(2, "a", 10.0, {0: 1.0, 1: 2.0})]
    insts = [InstanceSpec(0, "a", {"a": 0.5, "b": 0.5}),
             InstanceSpec(1, "a", {"a": 0.5, "b": 0.5})]
    # queue 0 runs a, b, a: two model changes -> two swaps
    spans = per_instance_makespan([[0, 1, 2], []], groups, insts)
    assert spans == pytest.approx([1.0 + 0.5 + 1.0 + 0.5 + 1.0, 0.0])
    # same groups sorted by model on instance 1: one swap, longer drains
    spans = per_instance_makespan([[], [0, 2, 1]], groups, insts)
    assert spans == pytest.approx([0.0, 2.0 + 2.0 + 0.5 + 2.0])


# ---------------------------------------------------------------------------
# threaded stress: real engines, churn, invariants on
# ---------------------------------------------------------------------------

def test_threaded_churn_soak_zero_violations_zero_leaks(monkeypatch):
    """Three real heterogeneous engines on their own threads; the driver
    churns submit/cancel/kill/migrate against them while every sampled
    round and controller tick re-checks the qlint invariants.  The run
    must end with every request terminal, conservation on every pool
    (including the dead and drained ones), and no violation raised on
    any thread (agent-thread exceptions surface via ``stop``)."""
    monkeypatch.setenv("QLINT_INVARIANTS", "1")
    monkeypatch.setenv("QLINT_INVARIANTS_SAMPLE", "3")
    from repro.launch import chaos
    from repro.serving import ThreadedCluster
    from repro.serving.faults import FaultPlan

    args = argparse.Namespace(
        arch="granite-3-2b", instances=3, slots=4, seed=0,
        max_new_tokens=8, scenario="none", hang_grace=None,
        retry_budget=2, threaded=True, hetero=True, routing="slice")
    clock, engines, agents, controller, make_engine, registry = \
        chaos.build_cluster(args, FaultPlan([], seed=0))

    t0 = clock()
    prefix = [1, 2, 3, 4]
    reqs = [make_request(prefix + list(range(10 + i, 22 + i)),
                         args.arch, ("interactive", "batch1")[i % 2],
                         arrival_time=t0 + 0.05 * i, max_new_tokens=8)
            for i in range(12)]

    cluster = ThreadedCluster(controller, agents, engines)
    cluster.start()
    killed = drained = False
    try:
        pending = list(reqs)
        deadline = t0 + 120.0
        while clock() < deadline:
            now = clock()
            while pending and pending[0].arrival_time <= now:
                controller.submit(pending.pop(0), now)
            submitted = len(reqs) - len(pending)
            if submitted >= 4:
                # cancel churn: cooperative flag, engines sweep it
                reqs[2].cancel_requested = True
                reqs[3].cancel_requested = True
            if not killed and submitted >= 6:
                controller.mark_dead(1, now, cause="churn kill")
                killed = True
            if not drained and not pending \
                    and controller.is_schedulable(0):
                controller.drain_instance(0, now, evict=True,
                                          cause="churn migrate")
                drained = True
            if not pending and all(chaos._terminal(r) for r in reqs) \
                    and not any(h.state == "draining"
                                for h in controller.health):
                break
            time.sleep(0.01)
    finally:
        cluster.stop()                       # re-raises agent errors

    assert killed and drained
    assert all(chaos._terminal(r) for r in reqs), \
        [r for r in reqs if not chaos._terminal(r)]
    controller.gc_groups()
    check_queue_layer(controller, where="churn/end")
    check_terminal_states(controller, engines=engines, where="churn/end")
    check_migration(controller, engines=engines, where="churn/end")
    for idx, eng in enumerate(engines):
        bm = eng.block_mgr
        check_block_manager(bm, where=f"churn/engine{idx}")
        assert not bm._seqs, f"engine{idx} leaked sequences"
        assert not [b for b, p in bm._pins.items() if p > 0], \
            f"engine{idx} leaked pins"
    # liveness: the churn actually served traffic (cancels may drop 2)
    served = sum(1 for r in reqs
                 if r.finished() and not r.failed and not r.rejected)
    assert served >= len(reqs) - 2 - controller.cfg.retry_budget
